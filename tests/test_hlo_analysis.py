"""Unit tests for the loop-aware HLO analyzer on synthetic HLO text, plus
an end-to-end validation against analytic FLOPs (subprocess: needs 8 host
devices)."""
import os
import subprocess
import sys
import textwrap

from repro.launch.hlo_analysis import _type_bytes, analyze_hlo

SYNTH = textwrap.dedent("""
    HloModule test

    %body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %ag = f32[8,8]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}
      %d = f32[8,8]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %w = f32[8,8]{1,0} parameter(1)
      %x = f32[4,8]{1,0} parameter(2)
    }

    %cond.1 (p: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %c = s32[] constant(5)
      %i = s32[] get-tuple-element(%p2), index=0
      %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (a: f32[4,8]) -> f32[8,8] {
      %a = f32[4,8]{1,0} parameter(0)
      %t = (s32[], f32[8,8]) tuple(...)
      %wh = (s32[], f32[8,8]) while(%t), condition=%cond.1, body=%body.1
      %ar = f32[8,8]{1,0} all-reduce(%a2), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
      %a2 = f32[8,8]{1,0} parameter(1)
    }
""")


def test_type_bytes():
    assert _type_bytes("f32[8,8]{1,0}") == 256
    assert _type_bytes("bf16[2,4]{1,0}") == 16
    assert _type_bytes("(f32[4]{0}, bf16[4]{0})") == 24
    assert _type_bytes("pred[]") == 1


def test_loop_multiplier_and_wire_model():
    res = analyze_hlo(SYNTH, 8)
    # all-gather in 5-trip loop: out 256B, g=2 → wire 128 × 5 = 640
    # all-reduce in main: 2·256·(4-1)/4 = 384
    assert res["collective_counts"]["n_all-gather"] == 5
    assert res["collective_counts"]["n_all-reduce"] == 1
    assert abs(res["collective_bytes_per_device"] - (640 + 384)) < 1e-6
    # f32 normalization halves everything here
    assert abs(res["collective_bytes_per_device_bf16norm"]
               - (640 + 384) / 2) < 1e-6
    # dot in loop: 2·64·8 = 1024 × 5
    assert res["dot_flops_per_device"] == 1024 * 5


E2E = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.hlo_analysis import analyze_hlo
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    D, F, L, B = 64, 128, 5, 16
    def model(params, x):
        def body(h, w):
            w1, w2 = w
            h = jnp.maximum(h @ w1, 0) @ w2
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(mesh, P("data", "model")))
            return h, None
        return jax.lax.scan(body, x, params)[0].mean()
    p = (jax.ShapeDtypeStruct((L, D, F), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "data", "model"))),
         jax.ShapeDtypeStruct((L, F, D), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "model", "data"))))
    x = jax.ShapeDtypeStruct((B, D), jnp.float32,
                             sharding=NamedSharding(mesh, P("data", "model")))
    with mesh:
        c = jax.jit(jax.grad(model)).lower(p, x).compile()
    res = analyze_hlo(c.as_text(), 8)
    analytic = 3 * 2 * B * D * F * 2 * L / 8   # fwd+bwd dots per device
    ratio = res["dot_flops_per_device"] / analytic
    assert 0.9 < ratio < 1.2, ratio
    print("E2E-OK", ratio)
""")


def test_analyzer_matches_analytic_flops():
    r = subprocess.run([sys.executable, "-c", E2E],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "E2E-OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
