"""Fault-tolerant serving pool (DESIGN.md §8): deterministic fault
injection, tier health supervision, failure hygiene, and request-level
retry with token-identical greedy recovery.

The fault matrix exercised here: {raise, hang, exhaust, nan} ×
{serial, concurrent} × {dense, paged} × {single-tier, multi-tier}.
Every recovery assertion compares against an unfailed reference run —
the §8 contract is that at temperature=0 a fault changes *when* tokens
arrive, never *which* tokens — and every paged scenario asserts the page
pool conservation invariant (zero leaks) after recovery.
"""
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.serve.engine import (EngineStallError, PageAllocator, Request,
                                RequestFailedError, StepReport, make_engine)
from repro.serve.faults import FAULT_KINDS, Fault, FaultyEngine, InjectedFault
from repro.serve.multi_engine import HealthPolicy, make_multi_engine
from repro.serve.scheduler import (DEGRADED, HEALTHY, PROBATION, QUARANTINED,
                                   apply_health)

ARCH = "mistral-nemo-12b"


def _cfg():
    return smoke_config(all_configs()[ARCH])


def _prompts(n, lo=4, hi=31, seed=3, vocab=512):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(x)).tolist()
            for x in rng.integers(lo, hi, n)]


def _reqs(prompts, max_new=6):
    return [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]


def _reference_streams(cfg, ctx, prompts, max_new=6, **kw):
    """Greedy streams of an unfailed single-engine run — the §8 oracle."""
    eng = make_engine(cfg, ctx, max_slots=2, max_len=64, decode_quantum=4,
                      **kw)
    reqs = _reqs(prompts, max_new)
    eng.run(reqs)
    return [r.out for r in reqs]


def _assert_pool_clean(meng):
    """Zero page leaks and empty slots on every tier after recovery."""
    for t in meng.tiers:
        eng = getattr(t.engine, "engine", t.engine)   # unwrap FaultyEngine
        assert all(r is None for r in eng.slot_req), t.name
        if eng.paged:
            eng.alloc.check()
            assert len(eng.alloc.free) == eng.alloc.usable_pages, t.name


# ------------------------------------------------------ deterministic faults
def test_fault_schedule_deterministic():
    """Same Fault fields → bit-identical schedule; the reproducibility
    contract that lets a failing scenario replay from its parameters."""
    f = Fault(kind="raise", p=0.3, seed=7)
    assert f.schedule(256) == Fault(kind="raise", p=0.3, seed=7).schedule(256)
    assert f.schedule(256) != Fault(kind="raise", p=0.3, seed=8).schedule(256)
    # explicit indices, periodic window, and n-step persistence
    assert Fault(kind="hang", at=(3,)).schedule(6) == \
        [False, False, False, True, False, False]
    assert Fault(kind="nan", every=3, phase=1).schedule(7) == \
        [False, True, False, False, True, False, False]
    assert Fault(kind="raise", at=(1,), n=3).schedule(5) == \
        [False, True, True, True, False]
    # prefix stability: a longer horizon never rewrites earlier draws
    assert Fault(kind="raise", p=0.5, seed=1).schedule(300)[:64] == \
        Fault(kind="raise", p=0.5, seed=1).schedule(64)


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault(kind="explode")
    with pytest.raises(ValueError):
        Fault(kind="raise", n=0)
    with pytest.raises(ValueError):
        Fault(kind="raise", p=1.5)
    with pytest.raises(ValueError):
        FaultyEngine(object(), ["raise"])          # not Fault instances
    assert set(FAULT_KINDS) == {"raise", "hang", "exhaust", "nan"}


def test_apply_health_capacity_mask():
    """Pure quarantine/probation law: quarantined takes nothing, probation
    at most one canary across slots+pending, healthy/degraded untouched."""
    caps = [4, 4, 4, 4]
    states = [HEALTHY, DEGRADED, QUARANTINED, PROBATION]
    assert apply_health(caps, states, [0, 0, 0, 0]) == [4, 4, 0, 1]
    assert apply_health(caps, states, [2, 2, 2, 1]) == [4, 4, 0, 0]
    assert apply_health([0], [PROBATION], [0]) == [0]   # canary ≤ capacity
    with pytest.raises(ValueError):
        apply_health([1], ["sick"], [0])
    with pytest.raises(ValueError):
        apply_health([1, 1], [HEALTHY], [0])


def test_plan_resume_law():
    """Pure resume law: re-prefill prompt+out with the leftover budget;
    None when the stream is already terminal (budget spent or EOS)."""
    from repro.serve.decode import plan_resume
    assert plan_resume([1, 2], [7, 8], 6) == ([1, 2, 7, 8], 4)
    assert plan_resume([1, 2], [], 6) == ([1, 2], 6)   # failed pre-decode
    assert plan_resume([1, 2], [7, 8], 2) is None      # budget spent
    assert plan_resume([1, 2], [7, 9], 6, eos_id=9) is None
    assert plan_resume([1, 2], [9, 7], 6, eos_id=9) == ([1, 2, 9, 7], 4)


def test_page_allocator_check_catches_corruption():
    """The conservation invariant names leaked and double-held pages."""
    alloc = PageAllocator(num_pages=9, max_slots=2, pages_per_slot=4)
    alloc.check()                                  # fresh pool is clean
    alloc.commit(0, 2)
    alloc.grow_to(0, 2)
    alloc.check()                                  # held pages are fine
    leaked = alloc.free.pop()                      # page falls off the books
    with pytest.raises(RuntimeError, match="leaked"):
        alloc.check()
    alloc.free.append(leaked)
    alloc.free.append(int(alloc.table[0, 0]))      # double-free: aliased page
    with pytest.raises(RuntimeError, match="double-held"):
        alloc.check()


def test_engine_abort_releases_everything(ctx):
    """Engine.abort empties the slots, returns the in-flight requests with
    their partial streams, releases every page, and leaves the engine
    reusable — the failure-hygiene primitive under `_reclaim_tier`."""
    cfg = _cfg()
    for paged in (False, True):
        kw = {"paged": True, "page_size": 8} if paged else {}
        eng = make_engine(cfg, ctx, max_slots=2, max_len=64,
                          decode_quantum=4, **kw)
        reqs = _reqs(_prompts(3, vocab=cfg.vocab), max_new=20)
        for r in reqs:
            eng.submit(r)
        eng.step()
        eng.step()                                     # both slots mid-flight
        aborted = eng.abort()
        assert len(aborted) == 2 and all(not r.done for r in aborted)
        assert all(len(r.out) > 0 for r in aborted)    # partial streams kept
        assert all(r is None for r in eng.slot_req)
        if paged:
            eng.alloc.check()
            assert len(eng.alloc.free) == eng.alloc.usable_pages
        # pending was NOT aborted — callers take_pending() first
        assert len(eng.take_pending()) == 1
        fresh = Request(rid=9, prompt=[1, 2, 3], max_new=4)
        eng.run([fresh])                               # engine still serves
        assert fresh.done and len(fresh.out) == 4


def test_faulty_engine_transparent_without_faults(ctx):
    """An empty fault schedule is a perfect proxy: same streams, same
    tier-facing surface as the wrapped engine."""
    cfg = _cfg()
    prompts = _prompts(3, vocab=cfg.vocab)
    eng = FaultyEngine(make_engine(cfg, ctx, max_slots=2, max_len=64,
                                   decode_quantum=4), [])
    reqs = _reqs(prompts)
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert [r.out for r in reqs] == _reference_streams(cfg, ctx, prompts)
    assert eng.fault_log == [] and eng.steps_seen > 0
    assert eng.max_len == 64                           # passthrough attrs


def test_faulty_engine_injects_on_schedule(ctx):
    """Each fault kind fires exactly where its schedule says."""
    cfg = _cfg()
    eng = FaultyEngine(
        make_engine(cfg, ctx, max_slots=2, max_len=64, decode_quantum=4),
        [Fault(kind="raise", at=(0,)), Fault(kind="nan", at=(1,)),
         Fault(kind="exhaust", at=(0,))])
    assert eng.plan_admission([Request(rid=0, prompt=[1], max_new=2)]) == 0
    assert eng.plan_admission([Request(rid=0, prompt=[1], max_new=2)]) == 1
    with pytest.raises(InjectedFault):
        eng.step()
    rep = eng.step()                                   # nan step: corrupt
    assert np.isnan(rep.dt) and rep.decoded > 10**6
    assert not eng.engine.has_work()                   # quantum was skipped
    assert eng.fault_log == [(0, "exhaust"), (0, "raise"), (1, "nan")]


# --------------------------------------------------- multi-tier fault matrix
@pytest.mark.parametrize("concurrent", [False, True],
                         ids=["serial", "concurrent"])
def test_raise_fault_recovery_token_identical(ctx, concurrent):
    """The flagship §8 scenario: a dense+paged pool loses its paged tier to
    consecutive step exceptions mid-run. The supervisor quarantines it,
    reclaims and re-routes its in-flight requests, and every greedy stream
    comes out byte-identical to the unfailed reference — with zero page
    leaks and the sick tier back to healthy through probation."""
    cfg = _cfg()
    prompts = _prompts(6, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "dense"},
        {"name": "paged", "paged": True, "page_size": 8},
    ], max_slots=2, max_len=64, decode_quantum=4, concurrent=concurrent,
        policy=HealthPolicy(quarantine_after=2, quarantine_cycles=1,
                            probation_steps=1, retry_backoff=0))
    sick = meng.tiers[1]
    sick.engine = FaultyEngine(sick.engine, [Fault(kind="raise", at=(2,),
                                                   n=2)])
    reqs = _reqs(prompts)
    meng.run(reqs)
    assert all(r.done for r in reqs) and not meng.dead_letters
    assert [r.out for r in reqs] == _reference_streams(cfg, ctx, prompts)
    assert any(k == "raise" for _, k in sick.engine.fault_log)
    assert sick.reclaims > 0, meng.stats()             # reclaim path taken
    states = [h["to"] for h in meng.health_log if h["tier"] == "paged"]
    assert QUARANTINED in states and PROBATION in states
    assert sick.health in (HEALTHY, PROBATION, DEGRADED)
    _assert_pool_clean(meng)
    # prompts/budgets restored to caller-visible originals after retries
    for r, p in zip(reqs, prompts):
        assert r.prompt == p and r.max_new == 6


def test_nan_report_quarantines_without_poisoning_tracker(ctx):
    """Corrupt StepReports (NaN dt, absurd token counts) are rejected
    before the shared tracker: the tier is quarantined, routing speeds
    stay finite, and the streams still match the unfailed reference."""
    cfg = _cfg()
    prompts = _prompts(5, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [{"name": "good"}, {"name": "bad"}],
                             max_slots=2, max_len=64, decode_quantum=4,
                             concurrent=False,
                             policy=HealthPolicy(quarantine_after=2,
                                                 quarantine_cycles=1,
                                                 probation_steps=1,
                                                 retry_backoff=0))
    bad = meng.tiers[1]
    bad.engine = FaultyEngine(bad.engine, [Fault(kind="nan", at=(1,), n=2)])
    reqs = _reqs(prompts)
    meng.run(reqs)
    assert all(r.done for r in reqs) and not meng.dead_letters
    assert [r.out for r in reqs] == _reference_streams(cfg, ctx, prompts)
    reasons = [h["reason"] for h in meng.health_log if h["tier"] == "bad"]
    assert any("corrupt StepReport" in r for r in reasons), meng.health_log
    for name in ("good", "bad"):
        assert np.isfinite(meng.tracker.throughput(name))
    assert meng.tracker.snapshot()["bad"].iters_done < 10**6


def test_exhaust_fault_reroutes_without_health_penalty(ctx):
    """Transient pool exhaustion is backpressure, not sickness: every
    admission probe on the starved tier reports zero capacity, the
    router's work conservation sends everything to the live tier, and the
    starved tier's health never leaves healthy."""
    cfg = _cfg()
    prompts = _prompts(4, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [{"name": "live"}, {"name": "dry"}],
                             max_slots=2, max_len=64, decode_quantum=4,
                             concurrent=False)
    dry = meng.tiers[1]
    dry.engine = FaultyEngine(dry.engine, [Fault(kind="exhaust", every=1)])
    reqs = _reqs(prompts, max_new=3)
    meng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(meng.assigned[r.rid] == "live" for r in reqs), meng.assigned
    assert dry.health == HEALTHY and dry.failures == 0
    assert not [h for h in meng.health_log if h["tier"] == "dry"]


def test_hang_deadline_watchdog_serial(ctx):
    """Serial mode: a hung quantum cannot be preempted, but the post-hoc
    watchdog still counts it as a failure — the tier is quarantined and
    its tokens (the work landed, late) are kept by the resume law, so
    recovery stays token-identical."""
    cfg = _cfg()
    prompts = _prompts(5, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "fast"},
        {"name": "wedged", "step_deadline_s": 0.02},
    ], max_slots=2, max_len=64, decode_quantum=4, concurrent=False,
        policy=HealthPolicy(quarantine_after=2, quarantine_cycles=1,
                            probation_steps=1, retry_backoff=0))
    wedged = meng.tiers[1]
    wedged.engine = FaultyEngine(
        wedged.engine, [Fault(kind="hang", at=(1,), n=2, hang_s=0.1)])
    reqs = _reqs(prompts)
    meng.run(reqs)
    assert all(r.done for r in reqs) and not meng.dead_letters
    assert [r.out for r in reqs] == _reference_streams(cfg, ctx, prompts)
    states = [h["to"] for h in meng.health_log if h["tier"] == "wedged"]
    assert QUARANTINED in states, meng.health_log


def test_hang_timeout_watchdog_concurrent(ctx):
    """Concurrent mode: the watchdog times out the hung step's future; the
    tier's engine stays owned by its thread (`inflight`) until the sleep
    ends, reclaim is deferred to `_poll_inflight`, and the pool finishes
    the whole workload token-identically meanwhile."""
    cfg = _cfg()
    prompts = _prompts(5, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "fast"},
        {"name": "wedged", "step_deadline_s": 0.3},
    ], max_slots=2, max_len=64, decode_quantum=4, concurrent=True,
        policy=HealthPolicy(quarantine_after=1, quarantine_cycles=1,
                            probation_steps=1, retry_backoff=0))
    wedged = meng.tiers[1]
    # prewarm both tiers so compile time cannot masquerade as a hang
    warm = _reqs(_prompts(2, seed=11, vocab=cfg.vocab), max_new=2)
    meng.run(warm)
    wedged.engine = FaultyEngine(
        wedged.engine, [Fault(kind="hang", at=(0,), hang_s=1.5)],
    )
    reqs = [Request(rid=10 + i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    meng.run(reqs)
    assert all(r.done for r in reqs) and not meng.dead_letters
    ref = _reference_streams(cfg, ctx, prompts)
    # warmup shifted nothing: greedy streams are position-independent
    assert [r.out for r in reqs] == ref
    reasons = [h["reason"] for h in meng.health_log if h["tier"] == "wedged"]
    assert any("still running" in r for r in reasons), meng.health_log
    assert wedged.inflight is None                     # thread collected
    _assert_pool_clean(meng)


# ----------------------------------------------- single tier, retry, budget
def test_single_tier_pool_survives_transient_fault(ctx):
    """A one-tier pool has nowhere to re-route — recovery is quarantine,
    backoff, probation, and the SAME tier finishing the work. Streams
    still match the unfailed reference."""
    cfg = _cfg()
    prompts = _prompts(3, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [{"name": "only", "paged": True,
                                         "page_size": 8}],
                             max_slots=2, max_len=64, decode_quantum=4,
                             concurrent=False,
                             policy=HealthPolicy(quarantine_after=1,
                                                 quarantine_cycles=1,
                                                 probation_steps=1,
                                                 retry_backoff=0))
    only = meng.tiers[0]
    only.engine = FaultyEngine(only.engine, [Fault(kind="raise", at=(1,))])
    reqs = _reqs(prompts)
    meng.run(reqs)
    assert all(r.done for r in reqs) and not meng.dead_letters
    assert [r.out for r in reqs] == _reference_streams(cfg, ctx, prompts)
    assert meng.retries > 0                            # resume law exercised
    _assert_pool_clean(meng)


def test_retry_budget_exhausted_dead_letters(ctx):
    """A tier that fails every step after its first drives each admitted
    request through the retry budget and into `dead_letters` as a typed
    `RequestFailedError` — original prompt/budget restored, partial stream
    kept, `done` False, pages released."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "only", "paged": True,
                                         "page_size": 8}],
                             max_slots=2, max_len=64, decode_quantum=4,
                             concurrent=False,
                             policy=HealthPolicy(quarantine_after=1,
                                                 quarantine_cycles=1,
                                                 probation_steps=1,
                                                 retry_budget=1,
                                                 retry_backoff=0))
    only = meng.tiers[0]
    only.engine = FaultyEngine(only.engine,
                               [Fault(kind="raise", at=(1,), n=10**6)])
    prompt = _prompts(1, vocab=cfg.vocab)[0]
    req = Request(rid=0, prompt=list(prompt), max_new=12)
    meng.run([req])                                    # returns, no raise
    assert not req.done
    assert 0 in meng.dead_letters
    assert isinstance(meng.dead_letters[0], RequestFailedError)
    assert "retry budget" in str(meng.dead_letters[0])
    assert req.prompt == prompt and req.max_new == 12  # identity restored
    assert len(req.out) > 0                            # partial stream kept
    assert meng.stats()["dead_letters"], meng.stats()
    _assert_pool_clean(meng)
    # a dead-lettered rid resubmits cleanly once the tier heals
    only.engine = only.engine.engine                   # unwrap the fault
    only.health, only.fail_streak = HEALTHY, 0
    req.out, req.done = [], False
    meng.run([req])
    assert req.done and len(req.out) == 12
    assert 0 not in meng.dead_letters                  # cleared on resubmit


def test_probation_routes_single_canary(ctx):
    """While a tier is on probation it is routed at most one request per
    cycle — the canary — until its clean steps restore the full share."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "a"}, {"name": "b"}],
                             max_slots=4, max_len=64, decode_quantum=4,
                             concurrent=False,
                             policy=HealthPolicy(quarantine_after=1,
                                                 quarantine_cycles=1,
                                                 probation_steps=3,
                                                 retry_backoff=0))
    b = meng.tiers[1]
    b.engine = FaultyEngine(b.engine, [Fault(kind="raise", at=(1,))])
    reqs = _reqs(_prompts(8, vocab=cfg.vocab), max_new=8)
    meng.run(reqs)
    assert all(r.done for r in reqs)
    probation_cycles = [c for c in meng.cycle_log
                        if c["health"]["b"] == PROBATION]
    assert probation_cycles, meng.health_log
    for c in probation_cycles:
        assert c["routed"]["b"] <= 1, c


# ----------------------------------------------------- stall-path hygiene
def test_stall_hygiene_dead_letters_and_clean_resubmit(ctx):
    """Satellite 2: when the stall guard trips, every unfinished request
    gets a terminal state (dead-lettered with the stall diagnostics), all
    pages are back in the pool, and a fresh submit on the SAME pool runs
    cleanly — no half-drained slots, no stale retry state."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "only", "paged": True,
                                         "page_size": 8}],
                             max_slots=1, max_len=64, decode_quantum=2,
                             concurrent=False)
    eng = meng.tiers[0].engine
    real_step = eng.step
    eng.step = lambda: StepReport()                    # wedged device
    reqs = [Request(rid=i, prompt=[3 + i, 4], max_new=2) for i in range(2)]
    with pytest.raises(EngineStallError, match="only:"):
        meng.run(reqs)
    assert all(not r.done for r in reqs)
    assert set(meng.dead_letters) == {0, 1}
    assert all(isinstance(e, RequestFailedError)
               for e in meng.dead_letters.values())
    assert all("stalled" in str(e) for e in meng.dead_letters.values())
    assert not meng.queue and not meng._delayed and not meng._resume
    _assert_pool_clean(meng)
    eng.step = real_step                               # device comes back
    fresh = Request(rid=0, prompt=[5, 6, 7], max_new=3)
    meng.run([fresh])                                  # same rid, clean pool
    assert fresh.done and len(fresh.out) == 3
    assert 0 not in meng.dead_letters


def test_submit_rejects_live_request_object(ctx):
    """A Request object is single-use until it terminates: double-submit
    while queued or in flight is a typed error, not silent aliasing."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "a"}],
                             max_slots=2, max_len=64, decode_quantum=4,
                             concurrent=False)
    req = Request(rid=0, prompt=[1, 2, 3], max_new=20)
    meng.submit(req)
    with pytest.raises(ValueError, match="single-use"):
        meng.submit(req)
    meng.step()                                        # admitted into a slot
    assert not req.done
    with pytest.raises(ValueError, match="single-use"):
        meng.submit(req)
    meng.drain()
    assert req.done
    req.out, req.done = [], False                      # terminal → reusable
    meng.submit(req)
    meng.drain()
    assert req.done
