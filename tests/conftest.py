"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only
repro.launch.dryrun (its own process) forces 512 host devices."""
import jax
import pytest

from repro.sharding.axes import single_device_ctx


@pytest.fixture(scope="session")
def ctx():
    return single_device_ctx()


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
