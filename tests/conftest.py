"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only
repro.launch.dryrun (its own process) forces 512 host devices."""
import jax
import pytest

from repro.sharding.axes import single_device_ctx


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: heavy system / arch-smoke tests — excluded from the tier-1 "
        "CI job (-m 'not slow'); a separate non-blocking job runs them")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # Without hypothesis, @given-decorated property tests become zero-arg
    # skips instead of erroring their whole module at collection (tier-1
    # runs with -x, so one missing dev dep used to kill the entire suite).
    import sys
    import types

    def _given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    class _Strategy:
        """Inert placeholder: any method (.map, .filter, …) chains to
        itself; only ever consumed by the skipping @given above."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

    _any = _Strategy()
    _hyp = types.ModuleType("hypothesis")
    _hyp.__is_shim__ = True        # lets importorskip-style guards detect us
    _hyp.given = _given
    _hyp.settings = lambda *a, **k: (lambda fn: fn)
    _hyp.assume = lambda *a, **k: True
    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "text"):
        setattr(_st, _name, lambda *a, **k: _any)
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def ctx():
    return single_device_ctx()


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
