"""Sharding rules + partitioner + elastic + data pipeline unit tests.
Multi-device behaviours (8 host devices) run in subprocesses because
XLA_FLAGS must be set before jax initialises."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.data.synthetic import SyntheticLM
from repro.sharding.axes import DEFAULT_RULES, logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_divisibility_drops_axis():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv_heads=8 does not divide model=16 → replicated
    spec = logical_to_spec(("batch", None, "kv_heads", None),
                           (256, 128, 8, 64), mesh)
    assert spec == P(None, None, None, None) or spec[2] is None
    spec = logical_to_spec(("vocab", "embed"), (102400, 5120), mesh)
    assert spec == P("model", "data")


def test_batch_uses_pod_and_data():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(("batch", "seq"), (256, 4096), mesh)
    assert spec == P(("pod", "data"), "model")


def test_axis_used_once():
    mesh = FakeMesh({"data": 16, "model": 16})
    # kv_seq takes model first; kv_heads can't reuse it
    spec = logical_to_spec(("batch", "kv_seq", "kv_heads", None),
                           (128, 32768, 16, 128), mesh)
    assert spec[1] == "model" and spec[2] is None


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 4096))
def test_spec_always_divides(dim):
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = logical_to_spec(("mlp",), (dim,), mesh)
    if spec[0] is not None:
        assert dim % 16 == 0


def test_synthetic_data_structure():
    d = SyntheticLM(vocab=97, seq_len=64, seed=1, copy_period=8)
    b = d.batch(4)
    assert b["tokens"].shape == (4, 64)
    # copy structure: every 8th target is predictable
    toks = np.concatenate([b["tokens"], b["targets"][:, -1:]], axis=1)
    for off in range(8, 65, 8):
        np.testing.assert_array_equal(toks[:, off], toks[:, off - 8])
    # determinism
    d2 = SyntheticLM(vocab=97, seq_len=64, seed=1, copy_period=8)
    np.testing.assert_array_equal(d2.batch(4)["tokens"], b["tokens"])


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.partitioner import HeterogeneousBatchPartitioner, Tier
    from repro.train.elastic import build_mesh, shrink_mesh
    from repro.sharding.axes import ShardCtx

    devs = jax.devices()
    assert len(devs) == 8

    # --- heterogeneous batch partitioner: 2 tiers, one slowed 3x
    def grad_fn(params, batch):
        g = jax.tree.map(lambda p: jnp.full_like(p, jnp.mean(batch["x"])), params)
        return g, {}
    params = {"w": jnp.zeros((4,))}
    tiers = [Tier("fast", devs[:6], grad_fn, slowdown=1.0),
             Tier("slow", devs[6:], grad_fn, slowdown=3.0)]
    part = HeterogeneousBatchPartitioner(tiers, quantum=2)
    batch = {"x": np.arange(24, dtype=np.float32)}
    for step in range(6):
        g, info = part.step(params, batch)
    # after warmup the fast tier gets more samples
    assert info["parts"][0] > info["parts"][1], info
    # weighted combine == global mean regardless of split
    assert abs(float(g["w"][0]) - float(np.mean(batch["x"]))) < 1e-5

    # --- elastic re-mesh drops the failed data row
    mesh = build_mesh(devs, model_size=2)        # (4 data, 2 model)
    ctx = ShardCtx(mesh=mesh)
    ctx2 = shrink_mesh(ctx, failed_indices={devs[2].id})
    assert ctx2.mesh.shape["data"] == 3
    assert ctx2.mesh.shape["model"] == 2
    print("MULTIDEV-OK")
""")


def test_multidevice_partitioner_and_elastic():
    r = subprocess.run([sys.executable, "-c", MULTIDEV],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEV-OK" in r.stdout, r.stdout + r.stderr


SHARDED_TRAIN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import all_configs, smoke_config
    from repro.sharding.axes import ShardCtx
    from repro.train.step import init_state, make_train_step
    from repro.train.optimizer import OptConfig
    from repro.models.model import synth_batch

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    ctx = ShardCtx(mesh=mesh)
    cfg = smoke_config(all_configs()["phi3.5-moe-42b-a6.6b"])
    ocfg = OptConfig(lr=1e-3)
    state = init_state(cfg, jax.random.PRNGKey(0), ctx, ocfg=ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, ctx, microbatches=2))
    batch = synth_batch(cfg, 8, 64, jax.random.PRNGKey(1))
    with mesh:
        state, m = step(state, batch)
        state, m = step(state, batch)
    assert np.isfinite(float(m["loss"])), m
    print("SHARDED-OK", float(m["loss"]))
""")


def test_sharded_train_step_8dev():
    """Real sharded execution (2×2×2 mesh) of an MoE smoke config — the
    shard_map MoE + CP attention actually run distributed, not just lower."""
    r = subprocess.run([sys.executable, "-c", SHARDED_TRAIN],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
