"""Pallas paged-attention decode kernel: kernel ↔ ref ↔ jnp-gather
equivalence (page sizes 8/16, multi-page slots, GQA + MLA + hybrid,
sharded and unsharded meshes), trash-page-0 isolation, and the engine's
live-prefix page-table bucketing. Kernel runs in interpret mode on CPU so
the real kernel body is exercised in tier-1."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.kernels.paged_attention import ops, ref
from repro.serve.decode import flash_decode_gqa, flash_decode_mla
from repro.serve.engine import Request, make_engine

KERNEL = "interpret"          # exercise the Pallas body even on CPU


def _cfg(arch="mistral-nemo-12b"):
    return smoke_config(all_configs()[arch])


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _gqa_case(page_size, seed=0, hkv=2, grp=3, dh=16, B=3, T=4):
    """Random pool + disjoint-page table + multi-page positions."""
    rng = np.random.default_rng(seed)
    N = 1 + B * T
    q = jnp.asarray(rng.normal(size=(B, hkv, grp, dh)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, page_size, hkv, dh)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, page_size, hkv, dh)), jnp.float32)
    pt = jnp.asarray(1 + rng.permutation(N - 1)[:B * T].reshape(B, T),
                     jnp.int32)
    # rows span 1..T live pages, incl. a page-boundary-straddling pos
    pos = jnp.asarray([page_size - 1, 2 * page_size, T * page_size - 1][:B],
                      jnp.int32)
    return q, pk, pv, pt, pos


@pytest.mark.parametrize("page_size", [8, 16])
@pytest.mark.parametrize("softcap", [0.0, 5.0])
def test_kernel_matches_ref_gqa(page_size, softcap):
    from repro.kernels.paged_attention.paged_attention import \
        paged_flash_decode_gqa
    q, pk, pv, pt, pos = _gqa_case(page_size)
    # base = shard · ps_loc: 0 is the unsharded case, page_size//2 the
    # second shard of a 2-way model axis (kernel must offset gpos)
    for base in (0, page_size // 2):
        got = paged_flash_decode_gqa(
            q, pk, pv, pt, pos, base, page_size=page_size, scale=0.25,
            softcap=softcap, interpret=True)
        want = ref.paged_flash_decode_gqa_ref(
            q, pk, pv, pt, pos, base, page_size=page_size, scale=0.25,
            softcap=softcap)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("page_size", [8, 16])
def test_kernel_matches_ref_mla(page_size):
    rng = np.random.default_rng(1)
    B, H, R, lora, T = 3, 4, 24, 16, 4
    N = 1 + B * T
    q = jnp.asarray(rng.normal(size=(B, H, R)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(N, page_size, R)), jnp.float32)
    pt = jnp.asarray(1 + rng.permutation(N - 1)[:B * T].reshape(B, T),
                     jnp.int32)
    pos = jnp.asarray([0, page_size + 2, T * page_size - 1], jnp.int32)
    got = ops.paged_attend_mla(q, pool, pt, pos, 0, 1, kv_lora=lora,
                               scale=0.2, impl=KERNEL)
    want = ref.paged_flash_decode_mla_ref(q, pool, pt, pos, 0,
                                          page_size=page_size, kv_lora=lora,
                                          scale=0.2)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------- full decode path, both flags
def test_flash_decode_gqa_kernel_vs_gather(ctx):
    """kernel and jnp-gather paths agree through the full flash_decode_gqa
    contract (write of the new token included)."""
    q, pk, pv, pt, pos = _gqa_case(8, seed=4)
    kn = jnp.asarray(np.random.default_rng(5).normal(size=(3, 2, 16)),
                     jnp.float32)
    vn = jnp.asarray(np.random.default_rng(6).normal(size=(3, 2, 16)),
                     jnp.float32)
    outs = {}
    for flag in (KERNEL, False):
        o, ck, cv = flash_decode_gqa(q, kn, vn, pk, pv, pos, window=0,
                                     scale=0.25, softcap=0.0, ctx=ctx,
                                     page_table=pt, paged_kernel=flag)
        outs[flag] = (np.asarray(o), np.asarray(ck), np.asarray(cv))
    for a, b in zip(outs[KERNEL], outs[False]):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_decode_mla_kernel_vs_gather(ctx):
    rng = np.random.default_rng(7)
    B, H, R, lora, T, ps = 2, 4, 24, 16, 3, 8
    N = 1 + B * T
    q = jnp.asarray(rng.normal(size=(B, H, R)), jnp.float32)
    pool = jnp.asarray(rng.normal(size=(N, ps, R)), jnp.float32)
    row = jnp.asarray(rng.normal(size=(B, R)), jnp.float32)
    pt = jnp.asarray(1 + rng.permutation(N - 1)[:B * T].reshape(B, T),
                     jnp.int32)
    pos = jnp.asarray([5, 2 * ps + 3], jnp.int32)
    outs = {}
    for flag in (KERNEL, False):
        o, ckv = flash_decode_mla(q, row, pool, pos, kv_lora=lora, scale=0.2,
                                  ctx=ctx, page_table=pt, paged_kernel=flag)
        outs[flag] = (np.asarray(o), np.asarray(ckv))
    for a, b in zip(outs[KERNEL], outs[False]):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_trash_page_isolation(ctx):
    """Garbage in the reserved trash page 0 (scribbles of inactive slots)
    must never reach a live slot's output — dead table entries point at
    page 0 and are position-masked/block-skipped."""
    q, pk, pv, pt, pos = _gqa_case(8, seed=8)
    kn = jnp.zeros((3, 2, 16), jnp.float32)
    vn = jnp.zeros((3, 2, 16), jnp.float32)
    # row 0 uses only 1 of its 4 table entries; point the dead ones at 0
    pt = pt.at[0, 1:].set(0)
    pos = pos.at[0].set(3)
    garbage = pk.at[0].set(1e4).astype(jnp.float32)
    for flag in (KERNEL, False):
        o_clean, _, _ = flash_decode_gqa(q, kn, vn, pk, pv, pos, window=0,
                                         scale=0.25, softcap=0.0, ctx=ctx,
                                         page_table=pt, paged_kernel=flag)
        o_trash, _, _ = flash_decode_gqa(q, kn, vn, garbage,
                                         pv.at[0].set(-1e4), pos, window=0,
                                         scale=0.25, softcap=0.0, ctx=ctx,
                                         page_table=pt, paged_kernel=flag)
        np.testing.assert_allclose(np.asarray(o_clean)[0],
                                   np.asarray(o_trash)[0],
                                   rtol=1e-6, atol=1e-6)


def test_paged_kernel_rejects_bad_args(ctx):
    """Typed errors (not asserts) on the user-reachable paged branches."""
    q, pk, pv, pt, pos = _gqa_case(8)
    kn = jnp.zeros((3, 2, 16), jnp.float32)
    with pytest.raises(ValueError, match="full-attention"):
        flash_decode_gqa(q, kn, kn, pk, pv, pos, window=16, scale=0.25,
                         softcap=0.0, ctx=ctx, page_table=pt)
    with pytest.raises(ValueError, match="update"):
        flash_decode_gqa(q, kn, kn, pk, pv, pos, window=0, scale=0.25,
                         softcap=0.0, ctx=ctx, page_table=pt, update=False)
    with pytest.raises(ValueError, match="batch"):
        flash_decode_gqa(q, kn, kn, pk, pv, pos[:2], window=0, scale=0.25,
                         softcap=0.0, ctx=ctx, page_table=pt)
    with pytest.raises(ValueError, match="impl"):
        ops.paged_attend_gqa(q, pk, pv, pt, pos, 0, 1, scale=0.25,
                             impl="nope")
    with pytest.raises(ValueError, match="paged_kernel"):  # at construction,
        make_engine(_cfg(), ctx, paged=True, page_size=8,  # not mid-serve
                    paged_kernel="kernal")


# ----------------------------------------------------- engine, end to end
def _serve(cfg, ctx, prompts, max_new, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_quantum", 4)
    eng = make_engine(cfg, ctx, **kw)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, reqs


@pytest.mark.parametrize("arch,page_size",
                         [("mistral-nemo-12b", 8),
                          ("deepseek-v2-236b", 16),
                          ("jamba-v0.1-52b", 8)])
def test_engine_kernel_matches_gather(arch, page_size, ctx):
    """GQA + MLA + hybrid: interpret-mode kernel decode is token-identical
    to the PR 2 jnp-gather escape hatch (multi-page contexts included)."""
    cfg = _cfg(arch)
    prompts = _prompts(cfg, [5, 11, 19], seed=2)
    kw = dict(paged=True, page_size=page_size)
    if arch == "jamba-v0.1-52b":
        kw["max_len"] = 48
    _, kern = _serve(cfg, ctx, prompts, 6, paged_kernel=KERNEL, **kw)
    _, gath = _serve(cfg, ctx, prompts, 6, paged_kernel=False, **kw)
    for a, b in zip(kern, gath):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)


def test_engine_live_prefix_buckets(ctx):
    """The kernel path hands the decode loop only the live page-column
    prefix: a short-context quantum must see a narrower table than
    max_len/page_size, bucketed to a power of two."""
    cfg = _cfg()
    eng = make_engine(cfg, ctx, max_slots=2, max_len=256, decode_quantum=4,
                      paged=True, page_size=8)
    assert eng.pages_per_slot == 32
    eng.pos_host[0] = 5                    # short ctx → the 8-page floor
    assert eng._live_page_table([0]).shape == (2, 8)
    eng.pos_host[1] = 100                  # 104 → 13 pages → bucket 16
    assert eng._live_page_table([0, 1]).shape == (2, 16)
    eng.pos_host[1] = 255                  # capped at the full table
    assert eng._live_page_table([0, 1]).shape == (2, 32)
    # gather escape hatch always sees the full table
    eng2 = make_engine(cfg, ctx, max_slots=2, max_len=256, decode_quantum=4,
                       paged=True, page_size=8, paged_kernel=False)
    eng2.pos_host[0] = 5
    assert eng2._live_page_table([0]).shape == (2, 32)


# 4-way model-sharded mesh: in-kernel base offsets + cross-shard combine
# (8-device subprocess, matching the test_paged.py convention)
_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import all_configs, smoke_config
    from repro.serve.decode import flash_decode_gqa
    from repro.serve.engine import Request, make_engine
    from repro.sharding.axes import ShardCtx

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh)

    # direct kernel-vs-gather on the sharded pool (interpret kernel): each
    # shard owns 2 of the 8 in-page offsets → exercises base = i·ps_loc
    rng = np.random.default_rng(0)
    B, hkv, grp, dh, T, ps = 2, 2, 2, 16, 3, 8
    N = 1 + B * T
    q = jnp.asarray(rng.normal(size=(B, hkv, grp, dh)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(N, ps, hkv, dh)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(N, ps, hkv, dh)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, hkv, dh)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, hkv, dh)), jnp.float32)
    pt = jnp.asarray(1 + rng.permutation(N - 1)[:B * T].reshape(B, T),
                     jnp.int32)
    pos = jnp.asarray([5, 2 * ps + 3], jnp.int32)
    outs = {}
    for flag in ("interpret", False):
        o, ck, cv = flash_decode_gqa(q, kn, vn, pk, pv, pos, window=0,
                                     scale=0.25, softcap=0.0, ctx=ctx,
                                     page_table=pt, paged_kernel=flag)
        outs[flag] = (np.asarray(o), np.asarray(ck), np.asarray(cv))
    for a, b in zip(outs["interpret"], outs[False]):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    print("KERNEL-SHARD-DIRECT-OK")

    # engine end-to-end on the same mesh: the interpret-mode Pallas kernel
    # (pinned — "auto" would resolve to the ref path on the CPU host and
    # make this a tautology) vs the gather escape hatch, token-identical
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    prompts = [np.random.default_rng(2).integers(0, cfg.vocab, n).tolist()
               for n in (5, 11, 19)]

    def serve(**kw):
        eng = make_engine(cfg, ctx, max_slots=2, max_len=64,
                          decode_quantum=4, paged=True, page_size=8, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return reqs

    kern = serve(paged_kernel="interpret")
    gath = serve(paged_kernel=False)
    for a, b in zip(kern, gath):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)
    print("KERNEL-SHARD-ENGINE-OK")
""")


@pytest.mark.slow
def test_paged_kernel_model_sharded():
    r = subprocess.run([sys.executable, "-c", _SHARDED],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "KERNEL-SHARD-DIRECT-OK" in r.stdout, (r.stdout[-2000:]
                                                  + r.stderr[-2000:])
    assert "KERNEL-SHARD-ENGINE-OK" in r.stdout, (r.stdout[-2000:]
                                                  + r.stderr[-2000:])
