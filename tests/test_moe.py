"""MoE block invariants: top-k mass conservation under infinite capacity,
capacity dropping, aux-loss stats, decode-path agreement."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs, smoke_config
from repro.configs.base import MoECfg, ModelConfig
from repro.models.moe import (aux_loss_from_stats, moe_block, moe_decode,
                              moe_defs)
from repro.sharding import params as prm
from repro.sharding.axes import single_device_ctx


def _cfg(E=8, k=2, cf=8.0, n_shared=0):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab=64, act="swiglu",
        moe=MoECfg(n_experts=E, top_k=k, d_expert=48, n_shared=n_shared,
                   capacity_factor=cf), param_dtype="float32")


def _dense_ref(cfg, p, x):
    """Oracle: every token through its top-k experts, no capacity limit."""
    m = cfg.moe
    B, S, D = x.shape
    xf = np.array(x.reshape(-1, D), np.float64)
    router = np.array(p["router"], np.float64)
    logits = xf @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :m.top_k]
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        gates = probs[t, top[t]]
        gates = gates / gates.sum()
        for gi, e in enumerate(top[t]):
            w_up = np.array(p["w_up"][e], np.float64)
            w_gate = np.array(p["w_gate"][e], np.float64)
            w_down = np.array(p["w_down"][e], np.float64)
            h = (xf[t] @ w_gate)
            h = h / (1 + np.exp(-h)) * (xf[t] @ w_up)
            out[t] += gates[gi] * (h @ w_down)
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference(ctx, key):
    cfg = _cfg(cf=16.0)   # capacity high enough that nothing drops
    p = prm.materialize(moe_defs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    out, stats = moe_block(cfg, p, x, ctx)
    ref = _dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.array(out), ref, rtol=2e-2, atol=2e-3)


def test_moe_decode_matches_block(ctx, key):
    cfg = _cfg(cf=16.0, n_shared=1)
    p = prm.materialize(moe_defs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32)) * 0.5
    out_dec = moe_decode(cfg, p, x, ctx)
    out_blk, _ = moe_block(cfg, p, x[:, None, :], ctx)
    np.testing.assert_allclose(np.array(out_dec), np.array(out_blk[:, 0]),
                               rtol=2e-2, atol=2e-3)


def test_capacity_dropping_reduces_output(ctx, key):
    """With tiny capacity, some tokens get zero routed contribution."""
    cfg_lo = _cfg(cf=0.1)
    p = prm.materialize(moe_defs(cfg_lo), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    out_lo, _ = moe_block(cfg_lo, p, x, ctx)
    cfg_hi = _cfg(cf=16.0)
    out_hi, _ = moe_block(cfg_hi, p, x, ctx)
    assert float(jnp.mean(jnp.abs(out_lo))) < float(jnp.mean(jnp.abs(out_hi)))


def test_aux_stats_are_distributions(ctx, key):
    cfg = _cfg()
    p = prm.materialize(moe_defs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    _, stats = moe_block(cfg, p, x, ctx)
    mean_prob, frac = np.array(stats[0]), np.array(stats[1])
    assert abs(mean_prob.sum() - 1.0) < 1e-3
    assert abs(frac.sum() - 1.0) < 1e-3
    aux = aux_loss_from_stats(cfg, stats)
    # balanced-uniform lower bound is aux_weight (E · Σ (1/E)·(1/E) = 1)
    assert float(aux) >= cfg.moe.aux_weight * 0.9


@pytest.mark.slow
def test_moe_grads_flow(ctx, key):
    cfg = _cfg()
    p = prm.materialize(moe_defs(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def f(p):
        out, stats = moe_block(cfg, p, x, ctx)
        return jnp.sum(out ** 2) + aux_loss_from_stats(cfg, stats)

    g = jax.grad(f)(p)
    gn = {k: float(jnp.sum(jnp.abs(v))) for k, v in g.items()}
    assert gn["router"] > 0 and gn["w_up"] > 0 and gn["w_down"] > 0


def test_smoke_moe_archs_route_all_experts(ctx):
    """On a big random batch every expert receives traffic (sanity that the
    sort/capacity plumbing isn't collapsing onto one expert)."""
    cfg = smoke_config(all_configs()["phi3.5-moe-42b-a6.6b"])
    p = prm.materialize(moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model),
                          jnp.float32).astype(cfg.pdtype)
    _, stats = moe_block(cfg, p, x, ctx)
    frac = np.array(stats[1])
    assert (frac > 0).sum() >= cfg.moe.n_experts // 2
