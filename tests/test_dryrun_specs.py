"""Dry-run plumbing: input_specs/cache defs construct for every cell and
shard cleanly on the production meshes (no compilation — fast)."""
import os
import subprocess
import sys
import textwrap

SPECS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.configs import SHAPES, all_configs, cell_supported, get_config
    from repro.launch.dryrun import input_specs, make_ctx
    from repro.serve.kv_cache import cache_bytes

    n = 0
    for arch in sorted(all_configs()):
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, _ = cell_supported(cfg, shape)
            if not ok:
                continue
            for multi in (False, True):
                ctx = make_ctx(cfg, multi, shape.kind)
                specs = input_specs(cfg, shape, ctx)
                for leaf in jax.tree.leaves(specs):
                    assert leaf.sharding is not None
                n += 1
            if shape.kind == "decode":
                # cache must fit HBM across devices with big headroom
                b = cache_bytes(cfg, shape.global_batch, shape.seq_len, 16)
                assert b / 256 < 8 * 2**30, (arch, sname, b)
    print("SPECS-OK", n)
""")


def test_all_cell_specs_construct():
    r = subprocess.run([sys.executable, "-c", SPECS],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SPECS-OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
    assert int(r.stdout.split()[-1]) == 66
