"""Pipeline parallelism: pipelined ≡ sequential reference (4-stage mesh,
subprocess for the multi-device runtime)."""
import os
import subprocess
import sys
import textwrap

from repro.train.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == 3 / 15
    assert bubble_fraction(1, 8) == 0.0


PIPE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.train.pipeline import pipeline_apply
    S, M, mb, D = 4, 8, 2, 16
    mesh = jax.make_mesh((4,), ("stage",))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))

    def layer_fn(W, h):
        return jnp.tanh(h @ W)

    with mesh:
        out = pipeline_apply(mesh, layer_fn, Ws, x)
    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s])
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    print("PIPE-OK", err)
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", PIPE],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPE-OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
