"""Speculative decoding correctness (DESIGN.md §7): greedy token
equivalence across architecture families and cache layouts, the pure
acceptance/emission law, the multi-token KV commit, nucleus sampling, and
the acceptance-scaled throughput accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import all_configs, smoke_config
from repro.models.draft import draft_from_target, soften_deep_layers
from repro.models.model import model_defs
from repro.serve.decode import (_filter_logits, _paged_write, _sample_tokens,
                                commit_rows, spec_candidates)
from repro.serve.engine import Engine, Request
from repro.serve.multi_engine import EngineTier, MultiEngine
from repro.sharding import params as prm

F32 = jnp.float32


# ------------------------------------------------------------ shared setup
def _materialize(cfg, seed=0):
    return prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))


def _prompts(cfg, lens=(4, 9, 17), seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _serve(cfg, params, ctx, prompts, *, max_new=6, **kw):
    eng = Engine(cfg, params, ctx, max_slots=2, max_len=64,
                 decode_quantum=3, **kw)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, [r.out for r in reqs]


# ------------------------------------------------- greedy token equivalence
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("arch", ["mistral-nemo-12b",     # GQA
                                  "deepseek-v2-236b",     # MLA + MoE
                                  "jamba-v0.1-52b"])      # hybrid SSM/attn
def test_greedy_spec_token_equivalence(arch, paged, ctx):
    """Draft-assisted greedy decode must emit the exact token stream of
    target-only decode — per family, dense and paged. The GQA case uses a
    truncated big/little pair (real nonzero acceptance, exercising
    multi-row commits); MLA/hybrid use an independent random draft whose
    proposals are mostly rejected (exercising the correction-only path)."""
    cfg = smoke_config(all_configs()[arch])
    params = _materialize(cfg)
    if arch == "mistral-nemo-12b":
        dcfg, dparams = draft_from_target(cfg, params, 1)
    else:   # cross-arch little model sharing the smoke vocab
        dcfg = smoke_config(all_configs()["mistral-nemo-12b"])
        dparams = _materialize(dcfg, seed=7)
    prompts = _prompts(cfg)
    _, plain = _serve(cfg, params, ctx, prompts)
    kw = dict(paged=True, page_size=8) if paged else {}
    eng, spec = _serve(cfg, params, ctx, prompts,
                       draft_cfg=dcfg, draft_params=dparams, spec_k=3, **kw)
    assert spec == plain
    assert eng.spec_proposed > 0
    if arch == "mistral-nemo-12b":
        assert eng.spec_accepted > 0        # truncated draft does agree


def test_greedy_spec_multi_engine_routing_unchanged(ctx):
    """A spec tier next to a plain tier in one pool: every request's output
    equals the single-engine greedy stream no matter which tier served it,
    and the pool surfaces per-tier acceptance stats."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = _materialize(cfg)
    dcfg, dparams = draft_from_target(cfg, params, 1)
    prompts = _prompts(cfg, lens=(4, 6, 9, 11, 17), seed=5)
    _, plain = _serve(cfg, params, ctx, prompts, max_new=5)

    def tier(name, **kw):
        return EngineTier(name, Engine(cfg, params, ctx, max_slots=2,
                                       max_len=64, decode_quantum=3, **kw))
    pool = MultiEngine([tier("plain"),
                        tier("spec", draft_cfg=dcfg, draft_params=dparams,
                             spec_k=3)], concurrent=False)
    reqs = [Request(rid=i, prompt=p, max_new=5)
            for i, p in enumerate(prompts)]
    pool.run(reqs)
    assert [r.out for r in reqs] == plain
    stats = pool.stats()["tiers"]
    assert set(pool.assigned.values()) == {"plain", "spec"}  # both served
    assert stats["plain"]["proposed"] == 0
    assert stats["spec"]["proposed"] >= stats["spec"]["accepted"] >= 0
    assert 0.0 <= stats["spec"]["acceptance"] <= 1.0


# ------------------------------------------------------ acceptance/emission
def _law_ref(proposals, corrections, accept, active, remaining, pos0,
             eos_id, max_len):
    """Serial reference of one speculative round for one slot."""
    k = len(proposals)
    m = 0
    while m < k and accept[m]:
        m += 1
    cand = [proposals[j] if j < m else corrections[m] for j in range(k + 1)]
    emitted = []
    if active:
        # token 0 is always emitted: an active slot has remaining ≥ 1 and
        # pos0 ≤ max_len−1 (the serial loop deactivates otherwise), and the
        # walls gate *further* emissions only
        emitted.append(cand[0])
        for j in range(1, k + 1):
            if j > m or len(emitted) >= remaining or pos0 + j >= max_len - 1:
                break
            if emitted[-1] == eos_id:
                break
            emitted.append(cand[j])
    return cand, emitted, m


def _law_case(rng, B=8, k=3, vocab=11, eos=5, max_len=32):
    proposals = rng.integers(0, vocab, (B, k))
    corrections = rng.integers(0, vocab, (B, k + 1))
    accept = rng.random((B, k)) < 0.6
    active = rng.random(B) < 0.85
    remaining = rng.integers(1, 8, B)
    pos0 = rng.integers(1, max_len, B)
    cand, emit, n, m = spec_candidates(
        jnp.asarray(proposals, jnp.int32), jnp.asarray(corrections, jnp.int32),
        jnp.asarray(accept), jnp.asarray(active),
        jnp.asarray(remaining, jnp.int32), jnp.asarray(pos0, jnp.int32),
        eos_id=eos, max_len=max_len)
    cand, emit, n, m = map(np.asarray, (cand, emit, n, m))
    for b in range(B):
        rcand, remit, rm = _law_ref(proposals[b], corrections[b], accept[b],
                                    active[b], remaining[b], pos0[b], eos,
                                    max_len)
        assert m[b] == rm
        assert n[b] == len(remit), (b, n[b], remit)
        assert list(cand[b, emit[b]]) == remit
        assert np.all(emit[b, :n[b]]) and not np.any(emit[b, n[b]:])


def test_acceptance_law_matches_serial_reference():
    """Fuzz `spec_candidates` against a per-slot serial reference: the
    accepted-prefix length, the emitted tokens, and the emission mask all
    agree for random verdicts / budgets / EOS hits / max_len walls."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        _law_case(rng)


def test_acceptance_law_all_accepted_emits_k_plus_one():
    """k accepted proposals → k+1 emitted tokens (the k drafts + bonus)."""
    k = 4
    cand, emit, n, m = spec_candidates(
        jnp.arange(k, dtype=jnp.int32)[None],
        jnp.full((1, k + 1), 9, jnp.int32),
        jnp.ones((1, k), bool), jnp.ones((1,), bool),
        jnp.full((1,), 16, jnp.int32), jnp.ones((1,), jnp.int32),
        eos_id=7, max_len=64)
    assert int(m[0]) == k and int(n[0]) == k + 1
    assert list(np.asarray(cand[0])) == list(range(k)) + [9]
    assert bool(np.all(np.asarray(emit)))


def test_acceptance_law_rejection_depth():
    """First rejection at depth d → d accepted drafts + the correction at
    depth d are emitted; later proposals are discarded."""
    accept = jnp.asarray([[True, False, True]])      # reject at depth 1
    cand, emit, n, m = spec_candidates(
        jnp.asarray([[3, 4, 5]], jnp.int32),
        jnp.asarray([[10, 11, 12, 13]], jnp.int32),
        accept, jnp.ones((1,), bool), jnp.full((1,), 16, jnp.int32),
        jnp.ones((1,), jnp.int32), eos_id=7, max_len=64)
    assert int(m[0]) == 1 and int(n[0]) == 2
    assert list(np.asarray(cand[0])[np.asarray(emit[0])]) == [3, 11]


def test_acceptance_law_truncation_and_inactive():
    """EOS inside the accepted prefix, the remaining-budget wall, the
    max_len wall, and inactive slots all cut the emission short."""
    args = dict(eos_id=7, max_len=64)
    P = jnp.asarray([[7, 4, 5]], jnp.int32)          # eos as first draft
    C = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    acc = jnp.ones((1, 3), bool)
    one = jnp.ones((1,), jnp.int32)
    _, emit, n, _ = spec_candidates(P, C, acc, jnp.ones((1,), bool),
                                    16 * one, one, **args)
    assert int(n[0]) == 1                            # nothing after EOS
    _, emit, n, _ = spec_candidates(P + 1, C, acc, jnp.ones((1,), bool),
                                    2 * one, one, **args)
    assert int(n[0]) == 2                            # budget wall
    _, emit, n, _ = spec_candidates(P + 1, C, acc, jnp.ones((1,), bool),
                                    16 * one, (64 - 3) * one, **args)
    assert int(n[0]) == 2                            # max_len wall
    _, emit, n, _ = spec_candidates(P + 1, C, acc, jnp.zeros((1,), bool),
                                    16 * one, one, **args)
    assert int(n[0]) == 0 and not bool(np.any(np.asarray(emit)))


def test_residual_rejection_sampling_preserves_target_law():
    """The acceptance rule as implemented — accept g~q iff u·q(g) < p(g),
    else resample from norm(max(p−q, 0)) — must reproduce p exactly.
    Checked analytically over random (p, q) pairs by enumerating the
    emitted-token law, the same identity DESIGN.md §7 derives."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        V = 7
        p = rng.dirichlet(np.ones(V))
        q = rng.dirichlet(np.ones(V))
        accept_prob = np.minimum(1.0, p / np.maximum(q, 1e-300))
        p_rej = 1.0 - np.sum(q * accept_prob)
        r = np.maximum(p - q, 0.0)
        r = r / r.sum() if r.sum() > 0 else p
        out = q * accept_prob + p_rej * r
        np.testing.assert_allclose(out, p, atol=1e-12)


def test_spec_pos_advance_matches_emissions(ctx):
    """Per quantum, every slot's device position (mirrored in `pos_host`)
    advances by exactly the number of tokens emitted for that slot — the
    accepted count, never the proposal count — and page tables grow
    accordingly (live pages ≥ ceil(pos/page_size) for every busy slot)."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = _materialize(cfg)
    dcfg, dparams = draft_from_target(cfg, params, 1)
    eng = Engine(cfg, params, ctx, max_slots=2, max_len=64, decode_quantum=2,
                 paged=True, page_size=8, draft_cfg=dcfg,
                 draft_params=dparams, spec_k=3)
    # max_new ≫ quantum_tokens so slots stay busy across step boundaries
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(rid=i, prompt=p, max_new=24))
    checked = 0
    while eng.has_work():
        before = eng.pos_host.copy()
        req_before = {i: r for i, r in enumerate(eng.slot_req)
                      if r is not None}
        emitted_before = {i: len(r.out) for i, r in req_before.items()}
        eng.step()
        # admission happens at the top of step(), so slots busy *before*
        # the step keep their request through this quantum (or retire)
        for i, r in req_before.items():
            adv = int(eng.pos_host[i] - before[i])
            assert adv == len(r.out) - emitted_before[i]
            assert adv <= eng.quantum_tokens
            checked += 1
        for i, r in enumerate(eng.slot_req):
            if r is not None:
                have = int(np.sum(eng.alloc.table[i] != 0))
                assert have * eng.page_size >= int(eng.pos_host[i])
    assert checked > 0


# ------------------------------------------------------- multi-token commit
def _commit_case(ctx, seed, B=3, K=4, T=6, ps=4, npages=25):
    """commit_rows on a paged leaf ≡ K sequential single-token writes with
    rejected rows routed to the trash page; live pages byte-identical."""
    rng = np.random.default_rng(seed)
    pool0 = jnp.asarray(rng.normal(size=(npages, ps, 2, 3)), F32)
    rows = jnp.asarray(rng.normal(size=(B, K, 2, 3)), F32)
    # disjoint live pages per slot (allocator invariant), page 0 = trash
    pt = jnp.asarray(rng.permutation(np.arange(1, npages))[:B * T]
                     .reshape(B, T), jnp.int32)
    lo = rng.integers(0, T * ps - K, B)
    pos0 = jnp.asarray(lo, jnp.int32)
    n = jnp.asarray(rng.integers(0, K + 1, B), jnp.int32)

    got = commit_rows(pool0, rows, pos0, n, ctx,
                      axes=(None, "kv_seq", None, None), page_table=pt)
    want = pool0
    for j in range(K):
        pos_j = jnp.where(j < n, pos0 + j, T * ps)
        want = _paged_write(want, rows[:, j], pt, pos_j, 0, 1)
    got, want = np.asarray(got), np.asarray(want)
    assert np.array_equal(got, want)                       # bit-identical
    # trash-page isolation: every live page outside the accepted target
    # positions is untouched by the whole commit
    touched = {(int(pt[b, (lo[b] + j) // ps]), (lo[b] + j) % ps)
               for b in range(B) for j in range(int(n[b]))}
    base = np.asarray(pool0)
    for pg in range(1, npages):
        for off in range(ps):
            if (pg, off) not in touched:
                assert np.array_equal(got[pg, off], base[pg, off]), (pg, off)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_commit_rows_property(seed):
    from repro.sharding.axes import single_device_ctx
    _commit_case(single_device_ctx(), seed)


def test_commit_rows_fixed_seeds(ctx):
    """Always-running (hypothesis-free) slice of the commit property,
    covering n=0, n=K, and page-boundary-straddling accept prefixes."""
    for seed in (0, 1, 2, 3, 4, 5, 6, 7):
        _commit_case(ctx, seed)


def test_commit_rows_dense_ring(ctx):
    """Dense windowed leaves: the multi-row commit lands rows at ring slots
    (pos0+j) % window exactly like the serial loop's single writes."""
    B, K, S, W = 2, 3, 8, 8
    rng = np.random.default_rng(2)
    cache0 = jnp.asarray(rng.normal(size=(B, S, 2, 3)), F32)
    rows = jnp.asarray(rng.normal(size=(B, K, 2, 3)), F32)
    pos0 = jnp.asarray([6, 30], jnp.int32)        # second slot wraps
    n = jnp.asarray([3, 2], jnp.int32)
    got = np.asarray(commit_rows(cache0, rows, pos0, n, ctx, window=W,
                                 axes=("batch", "kv_seq", None, None)))
    want = np.asarray(cache0).copy()
    for b in range(B):
        for j in range(int(n[b])):
            want[b, (int(pos0[b]) + j) % W] = rows[b, j]
    assert np.array_equal(got, want)


# ----------------------------------------------------------- nucleus (top-p)
def test_top_p_one_is_jaxpr_identical():
    """top_p=1.0 (and the 0.0 default) must add no HLO at all: the sampler
    traces to the exact same jaxpr as the pre-nucleus sampler."""
    x = jnp.zeros((2, 16), F32)
    key = jax.random.PRNGKey(0)

    def f(top_p):
        return jax.make_jaxpr(
            lambda l, k: _sample_tokens(l, k, temperature=0.8, top_k=4,
                                        top_p=top_p))(x, key)
    assert str(f(1.0)) == str(f(0.0))
    assert str(f(0.9)) != str(f(0.0))              # nucleus actually gates


def test_top_p_truncates_tail():
    """With p = [0.6, 0.3, 0.08, 0.02]: top_p=0.5 keeps {0}, 0.7 keeps
    {0,1} (0.6 alone is below the nucleus mass), 0.91 keeps {0,1,2};
    outside-nucleus tokens are never sampled, inside ones are."""
    probs = np.array([0.6, 0.3, 0.08, 0.02])
    logits = jnp.asarray(np.log(probs))[None]
    keys = jax.random.split(jax.random.PRNGKey(1), 300)

    def draws(top_p):
        f = jax.jit(lambda k: _sample_tokens(logits, k, temperature=1.0,
                                             top_k=0, top_p=top_p))
        return {int(f(k)[0]) for k in keys}
    assert draws(0.5) == {0}
    assert draws(0.7) == {0, 1}
    assert draws(0.91) <= {0, 1, 2}
    assert draws(0.91) >= {0, 1}
    assert draws(1.0) >= {0, 1, 2}
    lg = _filter_logits(logits, temperature=1.0, top_k=0, top_p=0.89)
    kept = np.asarray(jnp.exp(lg))[0] > 0
    assert list(kept) == [True, True, False, False]


def test_top_p_engine_plumbing(ctx):
    """`Engine(top_p=…)` reaches the device sampler: top_p=1.0 reproduces
    the plain sampled stream, tiny top_p collapses to greedy."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = _materialize(cfg)
    prompts = _prompts(cfg, lens=(5, 9))

    def serve(**kw):
        return _serve(cfg, params, ctx, prompts, max_new=6, **kw)[1]
    base = serve(temperature=0.9, sample_seed=1)
    assert serve(temperature=0.9, sample_seed=1, top_p=1.0) == base
    assert serve(temperature=0.9, sample_seed=1, top_p=1e-6) == serve()
    with pytest.raises(ValueError):
        Engine(cfg, params, ctx, top_p=1.5)


# --------------------------------------------------- throughput accounting
def test_multi_token_accounting_not_inflated(ctx):
    """StepReport.decoded and the tracker count *emissions*. With a random
    draft that the target rejects (acceptance ≈ 0) a spec_k=3 engine must
    report ≈1 token per slot-round — not 4 — so a spec tier cannot inflate
    the routing signal; and decoded always equals the tokens that actually
    reached request outputs."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = _materialize(cfg)
    dcfg = dataclasses.replace(
        smoke_config(all_configs()["mistral-nemo-12b"]), name="rand-draft")
    dparams = _materialize(dcfg, seed=11)
    eng = Engine(cfg, params, ctx, max_slots=2, max_len=64, decode_quantum=3,
                 draft_cfg=dcfg, draft_params=dparams, spec_k=3)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(_prompts(cfg))]
    decoded = accepted = proposed = 0
    for r in reqs:
        eng.submit(r)
    while eng.has_work():
        rep = eng.step()
        assert rep.accepted <= rep.proposed
        decoded += rep.decoded
        accepted += rep.accepted
        proposed += rep.proposed
    emitted = sum(len(r.out) for r in reqs)
    # each request's first token is sampled at prefill, the rest by the
    # decode loop — and decoded must count exactly those, never rounds×(k+1)
    assert decoded == emitted - len(reqs)
    rounds = proposed // eng.spec_k
    assert decoded <= accepted + rounds             # ≤ one correction/round
    assert (eng.spec_accepted, eng.spec_proposed) == (accepted, proposed)
    # the engine's own tracker saw only warm emission counts
    assert eng.tracker.snapshot()["decode"].iters_done <= decoded


# -------------------------------------------------- sampled spec statistics
@pytest.mark.slow
def test_sampled_spec_matches_target_distribution(ctx):
    """Fixed-seed statistical check that sampled speculative decoding
    preserves the target law.

    Measures the frequency of `out[1]` — the first token the decode loop
    itself emits (out[0] is sampled at prefill, identically in both
    engines, so it carries no information about the spec path).  top_k=16
    shrinks the support so empirical total variation concentrates: at
    temperature 1.0 the smoke model is near-uniform over the full vocab
    and empirical-vs-empirical TV at this N would be noise-dominated.
    The threshold self-calibrates against a plain-vs-plain null run at a
    different sample seed, so the test tracks the sampling noise floor
    instead of hard-coding it; a residual-sampling bug (e.g. emitting the
    draft's q instead of the residual of p) adds TV(p, q) on top of that
    floor and trips the ratio."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = _materialize(cfg)
    dcfg, dparams = draft_from_target(cfg, params, 1)
    prompt = _prompts(cfg, lens=(6,))[0]
    N, B = 384, 16

    def freqs(sample_seed, **kw):
        eng = Engine(cfg, params, ctx, max_slots=B, max_len=32,
                     decode_quantum=2, temperature=1.0, top_k=16,
                     sample_seed=sample_seed, **kw)
        reqs = [Request(rid=i, prompt=list(prompt), max_new=2)
                for i in range(N)]
        eng.run(reqs)
        counts = np.zeros(cfg.vocab)
        for r in reqs:
            counts[r.out[1]] += 1
        return counts / N

    def tv(a, b):
        return 0.5 * np.abs(a - b).sum()

    f_plain = freqs(9)
    f_null = freqs(123)                    # same law, independent draw
    f_spec = freqs(77, draft_cfg=dcfg, draft_params=dparams, spec_k=2)
    noise, dist = tv(f_plain, f_null), tv(f_plain, f_spec)
    assert dist < max(0.15, 2.0 * noise), (dist, noise)
