"""Window-aware CP attention (neighbor kv exchange) must equal the
single-device computation — 8-device subprocess, SWA arch (h2o)."""
import os
import subprocess
import sys
import textwrap

CP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import all_configs, smoke_config
    from repro.models.model import model_defs, loss_fn, synth_batch
    from repro.sharding import params as prm
    from repro.sharding.axes import ShardCtx

    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])  # window 32
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 4, 64, jax.random.PRNGKey(1))

    # single device reference
    mesh1 = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    ref = float(loss_fn(cfg, params, batch, ShardCtx(mesh=mesh1))[0])

    # 4-way model mesh: S_loc=16, window=32 → n_nb=2 < msize-1 → neighbor path
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh=mesh)
    with mesh:
        got = float(jax.jit(lambda p, b: loss_fn(cfg, p, b, ctx)[0])(params, batch))
    err = abs(got - ref)
    assert err < 2e-2, (got, ref)
    print("CPWIN-OK", got, ref)
""")


def test_window_cp_matches_single_device():
    r = subprocess.run([sys.executable, "-c", CP],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "CPWIN-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
