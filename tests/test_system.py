"""End-to-end behaviour tests: training actually learns the synthetic
structure, loss-fn internals (chunked CE ≡ direct CE), rope properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs, smoke_config
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticLM
from repro.models.layers import (apply_rope, chunked_ce_loss, logits_fn,
                                 rmsnorm, rope_tables)
from repro.models.model import model_defs
from repro.sharding import params as prm
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig


@pytest.mark.slow
def test_end_to_end_training_learns(tmp_path, ctx):
    """Few hundred steps on the copy-structured stream: loss must drop well
    below the unigram entropy (the model exploits the copy pattern)."""
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    ocfg = OptConfig(lr=3e-3, warmup_steps=10, decay_steps=120)
    lcfg = LoopConfig(total_steps=120, ckpt_every=60,
                      ckpt_dir=str(tmp_path), async_ckpt=False)
    data = SyntheticLM(cfg.vocab, 64, seed=0)
    loader = PrefetchLoader(data.iterator(8), ctx)
    res = train_loop(cfg, ocfg, lcfg, ctx, iter(loader), seed=0)
    loader.close()
    first = np.mean([r["loss"] for r in res.history[:5]])
    last = np.mean([r["loss"] for r in res.history[-5:]])
    assert last < first - 1.0, (first, last)


def test_chunked_ce_equals_direct(ctx, key):
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    params = prm.materialize(model_defs(cfg), key)
    B, S = 2, 48
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32).astype(cfg.pdtype)
    targets = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (B, S)) > 0.2) \
        .astype(jnp.float32)
    sl, sc = chunked_ce_loss(cfg, params["embed"], params["unembed"], h,
                             targets, mask, ctx, chunk=16)
    logits = logits_fn(cfg, params["embed"], params["unembed"], h, ctx)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    direct = jnp.sum((lse - lab) * mask)
    np.testing.assert_allclose(float(sl), float(direct), rtol=1e-4)
    assert float(sc) == float(jnp.sum(mask))


@settings(max_examples=30, deadline=None)
@given(pos=st.integers(0, 10_000), dim=st.sampled_from([16, 64, 128]))
def test_rope_preserves_norm(pos, dim):
    x = np.random.default_rng(pos).normal(size=(1, 1, 1, dim)) \
        .astype(np.float32)
    cos, sin = rope_tables(jnp.asarray([pos]), dim, 10_000.0)
    y = apply_rope(jnp.asarray(x), cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.array(y)),
                               np.linalg.norm(x), rtol=1e-5)


def test_rope_relative_property(key):
    """q(p1)·k(p2) depends only on p1 - p2."""
    dim = 32
    q = jax.random.normal(key, (1, 1, 1, dim))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dim))

    def dot_at(p1, p2):
        c1, s1 = rope_tables(jnp.asarray([p1]), dim, 10_000.0)
        c2, s2 = rope_tables(jnp.asarray([p2]), dim, 10_000.0)
        return float(jnp.sum(apply_rope(q, c1, s1) * apply_rope(k, c2, s2)))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 0) - dot_at(1007, 1000)) < 1e-3


def test_rmsnorm_scale_invariance(key):
    w = jnp.ones((32,))
    x = jax.random.normal(key, (2, 4, 32))
    y1 = rmsnorm(x, w, 1e-6)
    y2 = rmsnorm(x * 100.0, w, 1e-6)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-4)


def test_prefetch_loader_order(ctx):
    data = SyntheticLM(31, 16, seed=3)
    src = [data.batch(2) for _ in range(5)]
    loader = PrefetchLoader(iter(src), ctx, prefetch=2)
    got = list(loader)
    assert len(got) == 5
    for a, b in zip(src, got):
        np.testing.assert_array_equal(a["tokens"], np.array(b["tokens"]))
