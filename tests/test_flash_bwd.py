"""Flash-attention backward Pallas kernels vs autodiff-of-reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention_bwd import (
    flash_attention_bwd, flash_attention_fwd_lse)
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (False, 0, 0.0), (True, 0, 30.0),
])
def test_bwd_kernels_match_autodiff(causal, window, softcap, key):
    B, H, T, dh, dv = 2, 2, 128, 32, 16
    q = jax.random.normal(key, (B, H, T, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, dv), jnp.float32)
    do = jax.random.normal(jax.random.PRNGKey(3), (B, H, T, dv), jnp.float32)

    o, lse = flash_attention_fwd_lse(q, k, v, scale=0.2, causal=causal,
                                     window=window, softcap=softcap,
                                     bq=32, bk=32, interpret=True)
    dq, dk, dv_ = flash_attention_bwd(q, k, v, o, lse, do, scale=0.2,
                                      causal=causal, window=window,
                                      softcap=softcap, bq=32, bk=32,
                                      interpret=True)

    def f(q, k, v):
        out = flash_attention_ref(q, k, v, scale=0.2, causal=causal,
                                  window=window, softcap=softcap)
        return jnp.sum(out * do)

    rq, rk, rv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.array(dq), np.array(rq), atol=2e-3)
    np.testing.assert_allclose(np.array(dk), np.array(rk), atol=2e-3)
    np.testing.assert_allclose(np.array(dv_), np.array(rv), atol=2e-3)
