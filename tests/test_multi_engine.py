"""Multi-engine heterogeneous serving tiers: routing law, work-conserving
rebalancing, stall/pool backpressure rerouting, and multi-tier ≡
single-engine token equivalence at temperature=0."""
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.serve.engine import (EngineStallError, PromptTooLongError,
                                Request, StepReport, make_engine,
                                worst_case_pages)
from repro.serve.multi_engine import MultiEngine, make_multi_engine
from repro.serve.scheduler import request_units, route_requests, tier_speeds

ARCH = "mistral-nemo-12b"          # full attention → paged tiers exercised


def _cfg():
    return smoke_config(all_configs()[ARCH])


def _prompts(n, lo=4, hi=31, seed=3, vocab=512):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(x)).tolist()
            for x in rng.integers(lo, hi, n)]


# ------------------------------------------------------------ pure routing
def test_route_requests_converges_to_proportional_shares():
    """Skewed per-tier throughput → cumulative token-unit shares converge
    to the proportional law (3:1 within a few percent), with FIFO order
    preserved per tier. Pure host code: no engines, no timing."""
    speeds = [3.0, 1.0]
    done = [0, 0]
    rng = np.random.default_rng(0)
    for _ in range(200):
        units = [int(u) for u in rng.integers(5, 40, 8)]
        assign = route_requests(units, speeds, capacities=[8, 8])
        for i, idxs in enumerate(assign):
            assert idxs == sorted(idxs)            # FIFO within tier
            done[i] += sum(units[j] for j in idxs)
        assert sorted(assign[0] + assign[1]) == list(range(len(units)))
    share = done[0] / (done[0] + done[1])
    assert abs(share - 0.75) < 0.05, (done, share)


def test_route_requests_capacity_and_spill():
    """A tier with no capacity takes nothing; its share spills to the live
    tiers; requests beyond aggregate capacity stay queued."""
    units = [10, 10, 10, 10, 10]
    a = route_requests(units, [1.0, 5.0], [3, 0])
    assert a[1] == [] and a[0] == [0, 1, 2]        # spill + backpressure
    a = route_requests(units, [1.0, 5.0], [0, 0])
    assert a == [[], []]
    with pytest.raises(ValueError):
        route_requests(units, [1.0], [1, 1])


def test_route_requests_eligibility_and_constrained_first():
    """A request eligible on only one tier claims that tier's scarce
    capacity before universally-eligible requests spill onto it."""
    units = [10, 10, 10, 30]                       # last: long request
    eligible = [[True, True]] * 3 + [[False, True]]
    a = route_requests(units, [1.0, 1.0], [2, 1], eligible)
    assert 3 in a[1] and 3 not in a[0]
    assert len(a[0]) == 2 and len(a[1]) == 1       # capacity respected
    # nothing eligible anywhere stays queued rather than erroring
    a = route_requests([5], [1.0, 1.0], [1, 1], [[False, False]])
    assert a == [[], []]


def test_tier_speeds_prior_and_unit_cost():
    assert tier_speeds([0.0, 100.0], [2.0, 1.0], [1.0, 4.0]) == [2.0, 25.0]
    assert request_units(10, 6) == 16
    assert request_units(0, 0) == 1


# ------------------------------------------------------- engine tier surface
def test_step_report_and_tier_interface(ctx):
    """Engine.step exposes per-quantum token throughput; plan_admission and
    take_pending give a router slot- and pool-aware control."""
    cfg = _cfg()
    eng = make_engine(cfg, ctx, max_slots=2, max_len=64, decode_quantum=4)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(_prompts(3, vocab=cfg.vocab))]
    assert eng.plan_admission(reqs) == 2           # slot-capped
    for r in reqs:
        eng.submit(r)
    assert eng.has_work()
    rep = eng.step()
    assert isinstance(rep, StepReport)
    assert rep.admitted >= 1 and rep.decoded >= 1 and rep.dt > 0
    left = eng.take_pending()                      # un-admitted work back
    assert eng.pending == [] and all(isinstance(r, Request) for r in left)
    for r in left:
        eng.submit(r)
    eng.drain()
    assert not eng.has_work() and all(r.done for r in reqs)
    assert eng.decode_throughput() > 0


def test_plan_admission_pool_capped(ctx):
    """A paged engine's plan_admission stops at the pool's worst-case
    commit budget, not just at free slots."""
    cfg = _cfg()
    pages = 1 + 64 // 8                            # one full context only
    eng = make_engine(cfg, ctx, max_slots=4, max_len=64, paged=True,
                      page_size=8, num_pages=pages)
    reqs = [Request(rid=i, prompt=[1] * 40, max_new=20) for i in range(3)]
    assert eng.plan_admission(reqs) == 1, (
        "pool holds one worst-case context; admission must stop there")


# ----------------------------------------------------------- pool behaviour
def test_multi_engine_validation(ctx):
    cfg = _cfg()
    with pytest.raises(ValueError):
        MultiEngine([])
    meng = make_multi_engine(cfg, ctx, [{"name": "a"}, {"name": "b"}],
                             max_slots=2, max_len=64)
    with pytest.raises(ValueError):                # duplicate names
        make_multi_engine(cfg, ctx, [{"name": "a"}, {"name": "a"}],
                          max_slots=2, max_len=64)
    with pytest.raises(ValueError):                # shared engine object
        MultiEngine([type(meng.tiers[0])("x", meng.tiers[0].engine),
                     type(meng.tiers[0])("y", meng.tiers[0].engine)])
    with pytest.raises(ValueError):
        make_multi_engine(cfg, ctx, [{"name": "a", "kind": "gpu"}],
                          max_slots=2, max_len=64)
    with pytest.raises(ValueError):
        meng.submit(Request(rid=0, prompt=[], max_new=2))
    with pytest.raises(PromptTooLongError):        # too long for EVERY tier
        meng.submit(Request(rid=0, prompt=[1] * 64, max_new=2))


def test_multi_tier_token_equivalence(ctx):
    """The same workload through a heterogeneous dense+paged pool and
    through one engine produces identical greedy streams per request —
    which tier served a request must not change its tokens."""
    cfg = _cfg()
    prompts = _prompts(7, vocab=cfg.vocab)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "dense"},
        {"name": "paged", "paged": True, "page_size": 8},
    ], max_slots=2, max_len=64, decode_quantum=4)
    multi = [Request(rid=i, prompt=p, max_new=1 if i == 2 else 6)
             for i, p in enumerate(prompts)]
    meng.run(multi)
    assert all(r.done for r in multi)
    # both tiers actually served part of the workload
    assert all(t.routed > 0 for t in meng.tiers), meng.stats()
    assert set(meng.assigned) == {r.rid for r in multi}
    eng = make_engine(cfg, ctx, max_slots=2, max_len=64, decode_quantum=4)
    single = [Request(rid=i, prompt=p, max_new=1 if i == 2 else 6)
              for i, p in enumerate(prompts)]
    eng.run(single)
    for a, b in zip(multi, single):
        assert a.out == b.out, (a.rid, meng.assigned[a.rid], a.out, b.out)


def test_multi_tier_long_prompt_routes_to_capable_tier(ctx):
    """Prompts too long for the short tier are only eligible on the long
    tier; shorts and longs complete side by side."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [
        {"name": "short", "max_len": 48},
        {"name": "long", "max_len": 128},
    ], max_slots=2, decode_quantum=4)
    reqs = [Request(rid=0, prompt=_prompts(1, 90, 91, vocab=cfg.vocab)[0],
                    max_new=4)]
    reqs += [Request(rid=1 + i, prompt=p, max_new=4)
             for i, p in enumerate(_prompts(3, vocab=cfg.vocab))]
    meng.run(reqs)
    assert all(r.done for r in reqs)
    assert meng.assigned[0] == "long"


def test_stalled_tier_reroutes_work(ctx):
    """All slots of one tier are pinned by a long-running request; queued
    work must flow through the other tier instead of blocking (work
    conservation), and the pool must not stall."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "a"}, {"name": "b"}],
                             max_slots=1, max_len=64, decode_quantum=2,
                             concurrent=False)
    blocker = Request(rid=99, prompt=[1, 2, 3], max_new=40)
    tier_b = meng.tiers[1]
    tier_b.engine.submit(blocker)                  # pin b's only slot
    tier_b.engine.step()
    assert not tier_b.engine.free_slots()
    shorts = [Request(rid=i, prompt=p, max_new=3)
              for i, p in enumerate(_prompts(4, vocab=cfg.vocab))]
    meng.run(shorts)
    assert all(r.done for r in shorts)
    assert all(meng.assigned[r.rid] == "a" for r in shorts), meng.assigned
    tier_b.engine.drain()                          # let the blocker finish
    assert blocker.done


def test_pool_exhausted_tier_reroutes_work(ctx):
    """A paged tier whose pool cannot commit another request has zero
    effective capacity; queued work reroutes to the dense tier."""
    cfg = _cfg()
    pages = 1 + 64 // 8                            # one worst-case context
    meng = make_multi_engine(cfg, ctx, [
        {"name": "dense"},
        {"name": "paged", "paged": True, "page_size": 8,
         "num_pages": pages},
    ], max_slots=2, max_len=64, decode_quantum=2, concurrent=False)
    # the hog's worst case (prompt + max_new − 1 + quantum ≥ max_len) commits
    # every pool page, and its 50-token budget outlasts the whole short run
    hog = Request(rid=99, prompt=[1] * 10, max_new=50)
    paged = meng.tiers[1]
    paged.engine.submit(hog)                       # commits the whole pool
    paged.engine.step()
    assert paged.engine.plan_admission(
        [Request(rid=98, prompt=[1] * 8, max_new=8)]) == 0
    reqs = [Request(rid=i, prompt=p, max_new=3)
            for i, p in enumerate(_prompts(4, vocab=cfg.vocab))]
    meng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(meng.assigned[r.rid] == "dense" for r in reqs), meng.assigned
    paged.engine.drain()
    assert hog.done


def test_multi_engine_throughput_routing_skew(ctx):
    """With strongly skewed *measured* tier speeds, the proportional law
    routes most requests to the fast tier. Deterministic: the shared
    tracker is primed by hand instead of timing real quanta."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "fast"}, {"name": "slow"}],
                             max_slots=6, max_len=64, decode_quantum=4,
                             concurrent=False)
    for _ in range(6):                             # converge the EWMA
        meng.tracker.record("fast", 900, 1.0)
        meng.tracker.record("slow", 100, 1.0)
    # capacity is NOT binding (12 slots, 6 requests), so the deficit law —
    # not work-conserving spill — decides every placement
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(_prompts(6, vocab=cfg.vocab))]
    meng.run(reqs)
    assert all(r.done for r in reqs)
    fast = sum(1 for r in reqs if meng.assigned[r.rid] == "fast")
    assert fast >= 4, meng.assigned


def test_multi_engine_stall_reports_per_tier(ctx):
    """A hung tier (its step makes no progress — the analogue of a wedged
    device) trips the pool's guard with per-tier diagnostics instead of
    spinning forever."""
    cfg = _cfg()
    meng = make_multi_engine(cfg, ctx, [{"name": "only"}],
                             max_slots=1, max_len=64, decode_quantum=2,
                             concurrent=False)
    meng.tiers[0].engine.step = lambda: StepReport()    # hung device
    with pytest.raises(EngineStallError, match="only:"):
        meng.run([Request(rid=1, prompt=[4, 5], max_new=2)])
