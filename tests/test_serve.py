"""Serving correctness: prefill→decode ≡ full forward (per family), ring
buffers for sliding windows, engine end-to-end, whisper decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.models.layers import logits_fn
from repro.models.model import model_defs
from repro.models.transformer import lm_hidden
from repro.serve.decode import decode_step, whisper_decode_step
from repro.serve.engine import Request, make_engine
from repro.serve.prefill import prefill, whisper_prefill
from repro.sharding import params as prm

FAMS = ["mistral-nemo-12b", "gemma2-2b", "h2o-danube-1.8b",
        "deepseek-v2-236b", "mamba2-130m", "jamba-v0.1-52b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_full_forward(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    h, _ = lm_hidden(cfg, params, toks, ctx)
    ref = logits_fn(cfg, params["embed"], params["unembed"], h[:, -1:],
                    ctx)[:, 0]
    _, cache = prefill(cfg, params, toks[:, :S], ctx, max_len=S + 16)
    pos = jnp.full((B,), S, jnp.int32)
    logits, cache2 = decode_step(cfg, params, cache, toks[:, S], pos, ctx)
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, (arch, rel)
    # chained second step stays finite
    l2, _ = decode_step(cfg, params, cache2, toks[:, S], pos + 1, ctx)
    assert np.isfinite(np.array(l2)).all()


def test_sliding_window_ring_equivalence(ctx):
    """Decoding far past the window must match a fresh prefill of the same
    suffix (ring overwrite is exact)."""
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])  # window 32
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S, extra = 1, 40, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                              cfg.vocab)
    _, cache = prefill(cfg, params, toks[:, :S], ctx, max_len=96)
    logits = None
    for t in range(extra):
        pos = jnp.full((B,), S + t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache, toks[:, S + t], pos,
                                    ctx)
    h, _ = lm_hidden(cfg, params, toks, ctx)
    ref = logits_fn(cfg, params["embed"], params["unembed"], h[:, -1:],
                    ctx)[:, 0]
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, rel


def test_whisper_prefill_decode(ctx):
    cfg = smoke_config(all_configs()["whisper-large-v3"])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, Se = 2, 32
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, Se, cfg.d_model)) \
        * 0.1
    enc, cache = whisper_prefill(cfg, params, frames, ctx)
    assert enc.shape == (B, Se, cfg.d_model)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = whisper_decode_step(cfg, params, cache, tok,
                                        jnp.zeros((B,), jnp.int32), ctx)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.array(logits)).all()
    # greedy decode against the full decoder forward
    from repro.models.whisper import decode_hidden
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 5), 0, cfg.vocab)
    h = decode_hidden(cfg, params, toks, enc, ctx)
    ref = jnp.einsum("bd,dv->bv", h[:, -1],
                     params["embed"]["table"].T.astype(h.dtype))
    for t in range(5):
        logits, cache = whisper_decode_step(
            cfg, params, cache, toks[:, t], jnp.full((B,), t, jnp.int32), ctx)
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, rel


# ---------------------------------------------------- serving fast path
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b",      # sliding window
                                  "mistral-nemo-12b",     # full attention
                                  "deepseek-v2-236b"])    # MLA
def test_bucketed_prefill_equivalence(arch, ctx):
    """Prefill padded to a power-of-2 bucket with explicit prompt_len must
    match exact-length prefill: same last-token logits, and (the ring-pack
    gather check) the same continuation tokens when decoding onward."""
    from repro.serve.decode import decode_loop

    cfg = smoke_config(all_configs()[arch])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S, Sb, max_len = 2, 21, 32, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    ref_logits, ref_cache = prefill(cfg, params, toks, ctx, max_len=max_len)
    padded = jnp.pad(toks, ((0, 0), (0, Sb - S)))
    pl = jnp.full((B,), S, jnp.int32)
    logits, cache = prefill(cfg, params, padded, ctx, max_len=max_len,
                            prompt_len=pl)
    # bf16 reduction order differs between S and Sb chunkings → repo-wide
    # 3e-2 relative tolerance (same metric as the decode-vs-forward tests)
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref_logits)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref_logits)))))
    assert rel < 3e-2, (arch, rel)
    assert (np.array(logits).argmax(-1) ==
            np.array(ref_logits).argmax(-1)).all()
    # decode far enough past the window to exercise the ring wrap
    start = jnp.argmax(ref_logits, -1).astype(jnp.int32)
    args = (start, pl, jnp.ones(B, bool), jnp.full((B,), 99, jnp.int32))
    _, ref_toks, _ = decode_loop(cfg, params, ref_cache, *args, ctx,
                                 num_steps=12, eos_id=-1, max_len=max_len)
    _, fast_toks, _ = decode_loop(cfg, params, cache, *args, ctx,
                                  num_steps=12, eos_id=-1, max_len=max_len)
    np.testing.assert_array_equal(np.array(ref_toks), np.array(fast_toks))


def test_quantum_decode_equivalence(ctx):
    """N scanned decode steps ≡ N single decode steps (tokens and masking)."""
    from repro.serve.decode import decode_loop

    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S, N, max_len = 3, 12, 8, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits, cache = prefill(cfg, params, toks, ctx, max_len=max_len)
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
    pos0 = jnp.full((B,), S, jnp.int32)
    remaining = jnp.asarray([N + 5, 4, N + 5], jnp.int32)  # row 1 stops early
    (_, _, pos, active, rem, _), loop_toks, loop_msks = decode_loop(
        cfg, params, cache, tok0, pos0, jnp.ones(B, bool), remaining, ctx,
        num_steps=N, eos_id=-1, max_len=max_len)
    # reference: single steps with host-side masking
    cache_s, tok, pos_s = cache, tok0, pos0
    ref = np.full((N, B), -1, np.int32)
    alive = np.ones(B, bool)
    budget = np.array(remaining)
    for t in range(N):
        logits, cache_s = decode_step(cfg, params, cache_s, tok, pos_s, ctx)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        ref[t, alive] = np.array(nxt)[alive]
        budget -= alive
        pos_s = pos_s + jnp.asarray(alive)
        alive = alive & (budget > 0)
        tok = jnp.where(jnp.asarray(alive), nxt, tok)
    np.testing.assert_array_equal(np.array(loop_toks), ref)
    assert np.array_equal(np.array(active), alive)
    assert np.array_equal(np.array(pos), np.array(pos_s))
    assert np.array_equal(np.array(rem), budget)


@pytest.mark.parametrize("paged", [False, True])
def test_engine_fast_matches_legacy(ctx, paged):
    """Same workload through the fast path and the reference path produces
    identical streams; fast prefill compiles once per bucket. With
    paged=True the fast engine serves from the shared page pool (on a
    full-attention arch, so the pool is actually exercised)."""
    arch = "mistral-nemo-12b" if paged else "h2o-danube-1.8b"
    cfg = smoke_config(all_configs()[arch])
    rng = np.random.default_rng(3)
    lens = [4, 5, 9, 17, 18, 23, 63]        # buckets: 16, 32, 64;
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in lens]
    # 63 = max_len-1: prefill fills the penultimate slot, exactly one decode
    # step remains — the boundary where fast/legacy done-checks must agree

    def serve(fast):
        kw = dict(paged=True, page_size=8) if paged and fast else {}
        eng = make_engine(cfg, ctx, max_slots=3, max_len=64, fast=fast,
                          decode_quantum=4, **kw)
        # max_new=1 finishes at prefill — both paths must stop there
        reqs = [Request(rid=i, prompt=p, max_new=1 if i == 1 else 6)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return eng, reqs

    eng_f, fast = serve(True)
    _, legacy = serve(False)
    assert all(r.done for r in fast)
    for a, b in zip(fast, legacy):
        assert a.out == b.out, (a.rid, a.out, b.out)
    compiles = eng_f.prefill_compiles()
    assert compiles in (-1, 3), compiles   # one per bucket, not one per length


def test_engine_fast_mamba_exact_length_fallback(ctx):
    """Mamba mixers can't absorb pad tokens, so the fast engine falls back
    to exact-length (but still batched) prefill and stays correct."""
    cfg = smoke_config(all_configs()["mamba2-130m"])
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (5, 5, 9)]

    def serve(fast):
        eng = make_engine(cfg, ctx, max_slots=2, max_len=48, fast=fast,
                          decode_quantum=3)
        assert eng.pad_safe is False
        reqs = [Request(rid=i, prompt=p, max_new=4)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return reqs

    fast, legacy = serve(True), serve(False)
    for a, b in zip(fast, legacy):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)


def test_engine_continuous_batching(ctx):
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    eng = make_engine(cfg, ctx, max_slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).tolist(),
                    max_new=5) for i in range(5)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 5 for r in reqs)
    # determinism: same prompt → same continuation
    r2 = [Request(rid=9, prompt=reqs[0].prompt, max_new=5)]
    eng2 = make_engine(cfg, ctx, max_slots=3, max_len=64)
    eng2.run(r2)
    assert r2[0].out == reqs[0].out


# ------------------------------------------------------- on-device sampling
def test_sampling_determinism_and_greedy(ctx):
    """decode_loop sampling (ROADMAP "Real sampling"): temperature/top-k
    runs on device with the PRNG key as a scan carry — reproducible per
    seed, seed-sensitive, top_k=1 ≡ greedy, temperature=0 ≡ the default
    engine — and still exactly one host fetch per quantum."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (5, 9, 17)]

    def serve(**kw):
        eng = make_engine(cfg, ctx, max_slots=2, max_len=64,
                          decode_quantum=4, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return [r.out for r in reqs]

    greedy = serve()
    assert serve(temperature=0.0) == greedy            # static greedy path
    # top_k=1 collapses the categorical onto the argmax regardless of seed
    assert serve(temperature=0.7, top_k=1, sample_seed=5) == greedy
    a = serve(temperature=0.8, top_k=4, sample_seed=0)
    assert serve(temperature=0.8, top_k=4, sample_seed=0) == a
    assert serve(temperature=0.8, top_k=4, sample_seed=1) != a
    assert all(t >= 0 for out in a for t in out)       # real token ids
    # the FIRST token of a stream is sampled too (prefill argmax would pin
    # position 0 to greedy for every seed)
    hot = serve(temperature=5.0, sample_seed=2)
    assert [o[0] for o in hot] != [o[0] for o in greedy]


def test_sampling_engine_validation(ctx):
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    with pytest.raises(ValueError):
        make_engine(cfg, ctx, temperature=-0.1)
    with pytest.raises(ValueError):
        make_engine(cfg, ctx, top_k=-1)
    with pytest.raises(ValueError):               # typed at construction,
        make_engine(cfg, ctx, top_k=cfg.vocab + 1)  # not a lax.top_k trace
    with pytest.raises(ValueError):               # legacy path is greedy
        make_engine(cfg, ctx, fast=False, temperature=0.5)
