"""Serving correctness: prefill→decode ≡ full forward (per family), ring
buffers for sliding windows, engine end-to-end, whisper decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.models.layers import logits_fn
from repro.models.model import model_defs
from repro.models.transformer import lm_hidden
from repro.serve.decode import decode_step, whisper_decode_step
from repro.serve.engine import Request, make_engine
from repro.serve.prefill import prefill, whisper_prefill
from repro.sharding import params as prm

FAMS = ["mistral-nemo-12b", "gemma2-2b", "h2o-danube-1.8b",
        "deepseek-v2-236b", "mamba2-130m", "jamba-v0.1-52b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_full_forward(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    h, _ = lm_hidden(cfg, params, toks, ctx)
    ref = logits_fn(cfg, params["embed"], params["unembed"], h[:, -1:],
                    ctx)[:, 0]
    _, cache = prefill(cfg, params, toks[:, :S], ctx, max_len=S + 16)
    pos = jnp.full((B,), S, jnp.int32)
    logits, cache2 = decode_step(cfg, params, cache, toks[:, S], pos, ctx)
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, (arch, rel)
    # chained second step stays finite
    l2, _ = decode_step(cfg, params, cache2, toks[:, S], pos + 1, ctx)
    assert np.isfinite(np.array(l2)).all()


def test_sliding_window_ring_equivalence(ctx):
    """Decoding far past the window must match a fresh prefill of the same
    suffix (ring overwrite is exact)."""
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])  # window 32
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, S, extra = 1, 40, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0,
                              cfg.vocab)
    _, cache = prefill(cfg, params, toks[:, :S], ctx, max_len=96)
    logits = None
    for t in range(extra):
        pos = jnp.full((B,), S + t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache, toks[:, S + t], pos,
                                    ctx)
    h, _ = lm_hidden(cfg, params, toks, ctx)
    ref = logits_fn(cfg, params["embed"], params["unembed"], h[:, -1:],
                    ctx)[:, 0]
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, rel


def test_whisper_prefill_decode(ctx):
    cfg = smoke_config(all_configs()["whisper-large-v3"])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    B, Se = 2, 32
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, Se, cfg.d_model)) \
        * 0.1
    enc, cache = whisper_prefill(cfg, params, frames, ctx)
    assert enc.shape == (B, Se, cfg.d_model)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = whisper_decode_step(cfg, params, cache, tok,
                                        jnp.zeros((B,), jnp.int32), ctx)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.array(logits)).all()
    # greedy decode against the full decoder forward
    from repro.models.whisper import decode_hidden
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 5), 0, cfg.vocab)
    h = decode_hidden(cfg, params, toks, enc, ctx)
    ref = jnp.einsum("bd,dv->bv", h[:, -1],
                     params["embed"]["table"].T.astype(h.dtype))
    for t in range(5):
        logits, cache = whisper_decode_step(
            cfg, params, cache, toks[:, t], jnp.full((B,), t, jnp.int32), ctx)
    rel = float(np.max(np.abs(np.array(logits) - np.array(ref)))) / \
        max(1e-9, float(np.max(np.abs(np.array(ref)))))
    assert rel < 3e-2, rel


def test_engine_continuous_batching(ctx):
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    eng = make_engine(cfg, ctx, max_slots=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).tolist(),
                    max_new=5) for i in range(5)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 5 for r in reqs)
    # determinism: same prompt → same continuation
    r2 = [Request(rid=9, prompt=reqs[0].prompt, max_new=5)]
    eng2 = make_engine(cfg, ctx, max_slots=3, max_len=64)
    eng2.run(r2)
    assert r2[0].out == reqs[0].out
