"""Training substrate: optimizer reference check, int8 moments, microbatch
equivalence, gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.models.model import synth_batch
from repro.train.compression import (CompressionConfig, compress_decompress,
                                     init_residuals, wire_bytes)
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_moments,
                                   schedule)
from repro.train.step import init_state, make_train_step


def _ref_adamw(p, g, m, v, t, ocfg, lr):
    m2 = ocfg.b1 * m + (1 - ocfg.b1) * g
    v2 = ocfg.b2 * v + (1 - ocfg.b2) * g**2
    mh = m2 / (1 - ocfg.b1**t)
    vh = v2 / (1 - ocfg.b2**t)
    upd = mh / (np.sqrt(vh) + ocfg.eps) + ocfg.weight_decay * p
    return p - lr * upd, m2, v2


def test_adamw_matches_reference(key):
    ocfg = OptConfig(lr=1e-2, warmup_steps=0, decay_steps=10**9,
                     min_lr_ratio=1.0, weight_decay=0.1)
    p = {"w": jax.random.normal(key, (8, 16))}
    m = init_moments(p, ocfg)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 0.1}
    step = jnp.zeros((), jnp.int32)
    new_p, new_m, new_v, lr = adamw_update(p, g, m["m"], m["v"], step, ocfg)
    ref_p, _, _ = _ref_adamw(np.array(p["w"]), np.array(g["w"]),
                             np.zeros((8, 16)), np.zeros((8, 16)), 1.0,
                             ocfg, 1e-2)
    np.testing.assert_allclose(np.array(new_p["w"]), ref_p, rtol=1e-5,
                               atol=1e-6)


def test_schedule_warmup_cosine():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=110,
                     min_lr_ratio=0.1)
    assert float(schedule(ocfg, jnp.array(0))) == 0.0
    assert abs(float(schedule(ocfg, jnp.array(10))) - 1.0) < 1e-6
    assert abs(float(schedule(ocfg, jnp.array(110))) - 0.1) < 1e-6


def test_clip_by_global_norm(key):
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert abs(n2 - 1.0) < 1e-4


@pytest.mark.parametrize("moments", ["float32", "int8"])
def test_training_reduces_loss(moments, ctx):
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    ocfg = OptConfig(lr=3e-3, warmup_steps=5, decay_steps=200,
                     moments_dtype=moments)
    state = init_state(cfg, jax.random.PRNGKey(0), ctx, ocfg=ocfg)
    step = jax.jit(make_train_step(cfg, ocfg, ctx))
    batch = synth_batch(cfg, 4, 64, jax.random.PRNGKey(1))
    first = None
    for _ in range(15):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first - 0.5


def test_microbatch_equivalence(ctx):
    """mb=1 and mb=4 produce (nearly) the same update."""
    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    ocfg = OptConfig(lr=1e-3)
    batch = synth_batch(cfg, 4, 32, jax.random.PRNGKey(1))
    outs = []
    for mb in (1, 4):
        state = init_state(cfg, jax.random.PRNGKey(0), ctx, ocfg=ocfg)
        step = jax.jit(make_train_step(cfg, ocfg, ctx, microbatches=mb))
        state, m = step(state, batch)
        outs.append(state["params"])
    flat1 = jax.tree.leaves(outs[0])
    flat4 = jax.tree.leaves(outs[1])
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(flat1, flat4))
    assert err < 5e-2   # bf16 params + different reduction order


def test_compression_error_feedback(key):
    g = {"w": jax.random.normal(key, (64, 64))}
    # top-k is a much harsher compressor: EF still bounds the *cumulative*
    # error, but the running mean converges slower — per-kind thresholds
    for kind, tol in (("int8", 0.05), ("topk", 0.25)):
        ccfg = CompressionConfig(kind=kind, topk_frac=0.1)
        res = init_residuals(g)
        acc = jnp.zeros_like(g["w"])
        err_at = {}
        for i in range(20):
            dec, res = compress_decompress(g, res, ccfg)
            acc = acc + dec["w"]
            if i in (0, 19):
                err_at[i] = float(jnp.mean(jnp.abs(acc / (i + 1) - g["w"])))
        assert err_at[19] < tol, (kind, err_at)
        assert err_at[19] < err_at[0]     # EF reduces error over rounds
        assert wire_bytes(g, ccfg) < wire_bytes(g, CompressionConfig())


def test_int8_wire_savings():
    g = {"w": jnp.zeros((1024, 1024))}
    assert wire_bytes(g, CompressionConfig("int8")) < \
        wire_bytes(g, CompressionConfig()) / 3.9
