"""HBB scheduler tests: the §3.2 law (hypothesis property tests), the
two-stage pipeline engine, f convergence, and the paper's headline claim
(heterogeneous beats offload-only)."""
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chunking import accelerator_chunk, cpu_chunk, proportional_split
from repro.core.hbb import Body, Dynamic, Params
from repro.core.straggler import StragglerMonitor


# ------------------------------------------------------------- chunk law
@settings(max_examples=200, deadline=None)
@given(S_f=st.integers(1, 4096), f=st.floats(0.01, 1000.0),
       r=st.integers(0, 10**6), n=st.integers(1, 64))
def test_cpu_chunk_bounds(S_f, f, r, n):
    c = cpu_chunk(S_f, f, r, n)
    assert 0 <= c <= r
    if r > 0:
        assert c >= 1                       # progress guaranteed
        assert c <= max(1, int(min(S_f / f, r / (f + n))) )


@settings(max_examples=100, deadline=None)
@given(S_f=st.integers(1, 1024), f=st.floats(0.1, 100.0),
       n=st.integers(1, 16), r1=st.integers(1, 10**5), r2=st.integers(1, 10**5))
def test_cpu_chunk_monotone_in_remaining(S_f, f, n, r1, r2):
    lo, hi = sorted((r1, r2))
    assert cpu_chunk(S_f, f, lo, n) <= cpu_chunk(S_f, f, hi, n)


@settings(max_examples=100, deadline=None)
@given(S_f=st.integers(1, 4096), r=st.integers(0, 10**6))
def test_accelerator_chunk(S_f, r):
    c = accelerator_chunk(S_f, r)
    assert 0 <= c <= r and c <= S_f
    if r >= S_f:
        assert c == S_f                     # OpenMP-dynamic fixed chunk


@settings(max_examples=100, deadline=None)
@given(total=st.integers(1, 512).map(lambda x: x * 4),
       speeds=st.lists(st.floats(0.1, 50.0), min_size=1, max_size=8))
def test_proportional_split_conserves(total, speeds):
    parts = proportional_split(total, speeds, quantum=4)
    assert sum(parts) == total
    assert all(p % 4 == 0 and p >= 0 for p in parts)


def test_guided_tail():
    """Near the end, the guided operand takes over and drains the tail."""
    assert cpu_chunk(1024, 8.0, 10, 2) == 1
    r, drained = 1000, 0
    while r > 0 and drained < 10_000:
        c = cpu_chunk(64, 4.0, r, 2)
        r -= c
        drained += 1
    assert r == 0


# -------------------------------------------------------------- pipeline
class SimBody(Body):
    """Accelerator 8× faster than a core."""
    def operatorCPU(self, b, e):
        time.sleep((e - b) * 2e-4)

    def operatorFPGA(self, b, e):
        time.sleep((e - b) * 2.5e-5)


def _run(ncc, nfc, n=8000, chunk=512):
    p = Params(num_cpu_tokens=ncc, num_fpga_tokens=nfc, fpga_chunk=chunk,
               f0=4.0)
    return Dynamic(p).parallel_for(0, n, SimBody())


def test_parallel_for_exact_coverage():
    rep = _run(2, 1)
    covered = sorted((r.begin, r.end) for r in rep.records)
    pos = 0
    for b, e in covered:
        assert b == pos and e > b
        pos = e
    assert pos == 8000


def test_f_converges_to_true_ratio():
    rep = _run(2, 1, n=20000)
    assert 5.0 < rep.f_final < 12.0         # true ratio 8


def test_heterogeneous_beats_offload_only():
    """Paper §6: CC+FC reduces execution time vs accelerator-only."""
    t_fpga = min(_run(0, 1).wall_time for _ in range(2))
    t_het = min(_run(2, 1).wall_time for _ in range(2))
    assert t_het < t_fpga * 0.95


def test_static_vs_dynamic():
    p = Params(num_cpu_tokens=2, num_fpga_tokens=1, fpga_chunk=512,
               scheduler="static")
    rep = Dynamic(p).parallel_for(0, 8000, SimBody())
    assert sum(r.end - r.begin for r in rep.records) == 8000


# -------------------------------------------------------------- straggler
def test_straggler_detection_and_exclusion():
    mon = StragglerMonitor(beta=0.5, patience=2)
    for step in range(6):
        mon.observe("t0", 100, 0.1)
        mon.observe("t1", 100, 0.1)
        mon.observe("t2", 100, 1.0 if step >= 2 else 0.1)  # degrades
    assert "t2" in mon.excluded()
    speeds = mon.relative_speeds()
    assert "t2" not in speeds and set(speeds) == {"t0", "t1"}


def test_straggler_recovers_flags():
    mon = StragglerMonitor(beta=0.5, patience=5)
    mon.observe("a", 100, 0.1)
    mon.observe("b", 100, 1.0)      # slow once
    mon.observe("b", 100, 0.01)     # recovers (EWMA pulls back fast)
    mon.observe("b", 100, 0.01)
    assert mon.excluded() == []
