"""Per-architecture smoke tests: reduced config, one fwd+bwd step on CPU,
output shapes + finite loss/grads (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.models.model import loss_fn, model_defs, synth_batch
from repro.sharding import params as prm

pytestmark = pytest.mark.slow

ARCHS = sorted(all_configs())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_backward(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    defs = model_defs(cfg)
    params = prm.materialize(defs, jax.random.PRNGKey(0))
    batch = synth_batch(cfg, 2, 64, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        return jax.value_and_grad(lambda q: loss_fn(cfg, q, batch, ctx),
                                  has_aux=True)(p)

    (loss, metrics), grads = step(params)
    assert np.isfinite(float(loss)), arch
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab)) < 1.0, arch
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, arch
    # grads cover every parameter leaf
    assert len(jax.tree.leaves(grads)) == len(jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_logits_shape(arch, ctx):
    cfg = smoke_config(all_configs()[arch])
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(0))
    if cfg.enc_dec:
        from repro.models.whisper import decode_hidden, encode
        frames = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        enc = encode(cfg, params, frames, ctx)
        assert enc.shape == (2, 32, cfg.d_model)
        toks = jnp.zeros((2, 8), jnp.int32)
        h = decode_hidden(cfg, params, toks, enc, ctx)
        assert h.shape == (2, 8, cfg.d_model)
    else:
        from repro.models.transformer import lm_hidden
        batch = synth_batch(cfg, 2, 32, jax.random.PRNGKey(1))
        h, _ = lm_hidden(cfg, params, batch["tokens"], ctx,
                         batch.get("frontend_embed"))
        assert h.shape == (2, 32, cfg.d_model)
        assert not bool(jnp.any(jnp.isnan(h.astype(jnp.float32))))
