"""Checkpointing: atomic roundtrip, corruption fallback, async save, and
the fault-tolerant loop's restart behaviour (failure injection)."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.data.synthetic import SyntheticLM
from repro.train import checkpoint as ckpt
from repro.train.elastic import FailureInjector
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig


def _state(key):
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path, key):
    state = _state(key)
    ckpt.save(str(tmp_path), state, 7)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 7
    np.testing.assert_array_equal(np.array(restored["params"]["w"]),
                                  np.array(state["params"]["w"]))


def test_latest_valid_wins(tmp_path, key):
    state = _state(key)
    ckpt.save(str(tmp_path), state, 5)
    state2 = jax.tree.map(lambda x: x + 1, state)
    ckpt.save(str(tmp_path), state2, 10)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 10
    np.testing.assert_array_equal(np.array(restored["params"]["b"]),
                                  np.array(state2["params"]["b"]))


def test_corruption_falls_back(tmp_path, key):
    state = _state(key)
    ckpt.save(str(tmp_path), state, 5)
    ckpt.save(str(tmp_path), state, 10)
    # corrupt newest
    d = os.path.join(tmp_path, "step_10")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad\xbe\xef")
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 5


def test_async_save(tmp_path, key):
    state = _state(key)
    t = ckpt.save_async(str(tmp_path), state, 3)
    t.join()
    assert ckpt.available_steps(str(tmp_path)) == [3]


@pytest.mark.slow
def test_loop_restarts_from_checkpoint(tmp_path, ctx):
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    ocfg = OptConfig(lr=1e-3, warmup_steps=2, decay_steps=40)
    lcfg = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=str(tmp_path),
                      async_ckpt=False, max_restarts=2)
    data = SyntheticLM(cfg.vocab, 32, seed=0)
    inj = FailureInjector({6: RuntimeError("simulated node failure")})
    res = train_loop(cfg, ocfg, lcfg, ctx, iter(data.iterator(2)),
                     failure_injector=inj, seed=0)
    assert res.restarts == 1
    assert inj.fired == [6]
    assert int(res.state["step"]) == 12
    # steps 5..6 re-ran after restart from step 4
    steps = [r["step"] for r in res.history]
    assert steps.count(5) == 2 or steps.count(6) >= 1


@pytest.mark.slow
def test_loop_gives_up_after_max_restarts(tmp_path, ctx):
    cfg = smoke_config(all_configs()["h2o-danube-1.8b"])
    lcfg = LoopConfig(total_steps=8, ckpt_every=100, ckpt_dir=str(tmp_path),
                      async_ckpt=False, max_restarts=1)
    data = SyntheticLM(cfg.vocab, 32, seed=0)
    inj = FailureInjector({2: RuntimeError("f1")})

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 2:
                raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        train_loop(cfg, OptConfig(), lcfg, ctx, iter(data.iterator(2)),
                   failure_injector=AlwaysFail({}), seed=0)
