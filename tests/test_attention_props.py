"""Property tests on the block-pair enumeration (hypothesis-based).

Split out of test_attention.py so a missing hypothesis install skips this
module instead of erroring the whole attention suite at collection.
"""
import pytest

_hyp = pytest.importorskip("hypothesis")
if getattr(_hyp, "__is_shim__", False):     # conftest stub, not the real lib
    pytest.skip("hypothesis not installed", allow_module_level=True)

from hypothesis import given, settings, strategies as st

from repro.models.attention import block_pairs


@settings(max_examples=60, deadline=None)
@given(Tq=st.integers(8, 96), Tk=st.integers(8, 96),
       qc=st.sampled_from([8, 16, 32]), kc=st.sampled_from([8, 16, 32]),
       window=st.sampled_from([0, 8, 24]), causal=st.booleans())
def test_block_pairs_cover_all_unmasked(Tq, Tk, qc, kc, window, causal):
    """Every (i,j) the mask allows lies in some enumerated block pair, and
    enumerated pairs contain at least one allowed position."""
    qo = max(0, Tk - Tq) if causal else 0
    pairs = set(map(tuple, block_pairs(Tq, Tk, qc, kc, causal=causal,
                                       window=window, q_offset=qo)))
    for i in range(Tq):
        gi = i + qo
        for j in range(Tk):
            allowed = (not causal or j <= gi) and \
                      (not window or j > gi - window)
            if allowed:
                assert (i // qc, j // kc) in pairs
    # no fully-masked pair in the list
    for (pi, pj) in pairs:
        any_ok = False
        for i in range(pi * qc, min(pi * qc + qc, Tq)):
            gi = i + qo
            lo = max(pj * kc, 0)
            hi = min(pj * kc + kc, Tk)
            for j in range(lo, hi):
                if (not causal or j <= gi) and (not window or j > gi - window):
                    any_ok = True
                    break
            if any_ok:
                break
        assert any_ok, (pi, pj)
