"""Mamba mixers: SSD vs naive recurrence (property-swept), chunked
mamba1 vs step decoding, padding no-op invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, SSMCfg
from repro.models.mamba import (mamba1_defs, mamba1_mixer, mamba1_state_defs,
                                mamba1_step, mamba2_defs, mamba2_mixer,
                                mamba2_state_defs, mamba2_step, ssd_scan)
from repro.sharding import params as prm


def _naive_ssd(xh, dta, Bm, Cm):
    B, S, H, P = xh.shape
    h = np.zeros((B, H, P, Bm.shape[-1]), np.float64)
    ys = []
    for t in range(S):
        da = np.exp(np.asarray(dta[:, t], np.float64))
        h = h * da[..., None, None] + np.einsum(
            "bn,bhp->bhpn", np.asarray(Bm[:, t], np.float64),
            np.asarray(xh[:, t], np.float64))
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(Cm[:, t], np.float64),
                            h))
    return np.stack(ys, 1), h


@settings(max_examples=15, deadline=None)
@given(S=st.integers(4, 70), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 100))
def test_ssd_scan_matches_naive(S, chunk, seed):
    key = jax.random.PRNGKey(seed)
    B, H, P, N = 2, 2, 4, 8
    xh = jax.random.normal(key, (B, S, H, P))
    dta = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                             (B, S, H)))
    Bm = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, N))
    Cm = jax.random.normal(jax.random.PRNGKey(seed + 3), (B, S, N))
    y, h = ssd_scan(xh, dta, Bm, Cm, chunk=chunk)
    yn, hn = _naive_ssd(np.array(xh), np.array(dta), np.array(Bm),
                        np.array(Cm))
    np.testing.assert_allclose(np.array(y), yn, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(h), hn, rtol=1e-3, atol=1e-3)


def _cfg(version):
    return ModelConfig(
        name=f"m{version}", family="ssm", n_layers=2, d_model=32, n_heads=0,
        n_kv_heads=0, head_dim=0, d_ff=0, vocab=64, use_rope=False,
        ssm=SSMCfg(d_state=8, d_conv=4, expand=2, head_dim=8,
                   version=version, chunk=16),
        param_dtype="float32")


@pytest.mark.parametrize("version", [1, 2])
def test_full_vs_step_decode(version, ctx):
    cfg = _cfg(version)
    defs = mamba1_defs(cfg) if version == 1 else mamba2_defs(cfg)
    p = prm.materialize(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 48, 32)) * 0.5
    mixer = mamba1_mixer if version == 1 else mamba2_mixer
    step = mamba1_step if version == 1 else mamba2_step
    sdefs = mamba1_state_defs if version == 1 else mamba2_state_defs
    y_full = mixer(cfg, p, x, ctx)
    stt = prm.materialize(sdefs(cfg, 2), jax.random.PRNGKey(0))
    outs = []
    for t in range(48):
        o, stt = step(cfg, p, x[:, t], stt, ctx)
        outs.append(o)
    y_step = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.array(y_full), np.array(y_step), atol=5e-3)


@pytest.mark.parametrize("version", [1, 2])
def test_prefill_state_continues_exactly(version, ctx):
    """mixer(return_state) at S, then steps, ≡ mixer over S+k."""
    cfg = _cfg(version)
    defs = mamba1_defs(cfg) if version == 1 else mamba2_defs(cfg)
    p = prm.materialize(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 40, 32)) * 0.5
    mixer = mamba1_mixer if version == 1 else mamba2_mixer
    step = mamba1_step if version == 1 else mamba2_step
    S = 32
    _, stt = mixer(cfg, p, x[:, :S], ctx, return_state=True)
    outs = []
    for t in range(S, 40):
        o, stt = step(cfg, p, x[:, t], stt, ctx)
        outs.append(o)
    y_cont = jnp.stack(outs, 1)
    y_full = mixer(cfg, p, x, ctx)[:, S:]
    np.testing.assert_allclose(np.array(y_cont), np.array(y_full), atol=5e-3)


def test_padding_is_noop(ctx):
    """Non-multiple-of-chunk S must equal the value computed at chunk=1."""
    cfg = _cfg(2)
    p = prm.materialize(mamba2_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 37, 32)) * 0.5
    import dataclasses
    cfg1 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, chunk=37))
    y16 = mamba2_mixer(cfg, p, x, ctx)
    y37 = mamba2_mixer(cfg1, p, x, ctx)
    np.testing.assert_allclose(np.array(y16), np.array(y37), atol=2e-3)
