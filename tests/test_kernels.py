"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracle (assignment requirement c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gemm.gemm import gemm, vmem_bytes
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.grouped_gemm.grouped_gemm import grouped_gemm
from repro.kernels.grouped_gemm.ref import grouped_gemm_ref
from repro.kernels.ssd.ref import ssd_intra_chunk_ref
from repro.kernels.ssd.ssd import ssd_intra_chunk


@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 512, 384),
                                   (64, 256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(M, N, K, dtype, key):
    a = jax.random.normal(key, (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N),
                          jnp.float32).astype(dtype)
    out = gemm(a, b, bm=64, bn=64, bk=128, interpret=True)
    ref = gemm_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 64), (128, 64, 128)])
def test_gemm_block_shapes(bm, bn, bk, key):
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    out = gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.array(out), np.array(gemm_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_gemm_vmem_model():
    # paper Table 2 analogue: the capacity knob must fit VMEM
    assert vmem_bytes(256, 256, 512) < 16 * 2**20


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (False, 0, 0.0), (True, 0, 30.0),
    (True, 32, 50.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(causal, window, softcap, dtype, key):
    B, H, T, dh, dv = 2, 3, 128, 32, 16
    q = jax.random.normal(key, (B, H, T, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, dh),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, dv),
                          jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, scale=0.18, causal=causal, window=window,
                          softcap=softcap, bq=32, bk=32, interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.18, causal=causal,
                              window=window, softcap=softcap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("E,M,K,N", [(4, 64, 128, 64), (8, 128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm_sweep(E, M, K, N, dtype, key):
    a = jax.random.normal(key, (E, M, K), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (E, K, N),
                          jnp.float32).astype(dtype)
    out = grouped_gemm(a, w, bm=32, bn=32, bk=64, interpret=True)
    ref = grouped_gemm_ref(a, w)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(ref, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("Q,P,N", [(32, 16, 32), (64, 64, 128)])
def test_ssd_kernel_sweep(Q, P, N, key):
    G = 4
    x = jax.random.normal(key, (G, Q, P), jnp.float32)
    cs = jnp.cumsum(
        -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (G, Q, 1))),
        axis=1)
    B = jax.random.normal(jax.random.PRNGKey(2), (G, Q, N), jnp.float32)
    C = jax.random.normal(jax.random.PRNGKey(3), (G, Q, N), jnp.float32)
    y, st = ssd_intra_chunk(x, cs, B, C, interpret=True)
    yr, str_ = ssd_intra_chunk_ref(x, cs, B, C)
    np.testing.assert_allclose(np.array(y), np.array(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.array(st), np.array(str_), rtol=1e-5,
                               atol=1e-5)
