"""Assigned-architecture configs must match the published numbers exactly
(deliverable f), and the cache/roofline accounting must be consistent."""
import pytest

from repro.configs import SHAPES, all_configs, cell_supported, get_config

EXPECT = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102_400),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32_064),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256_000),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32_000),
    "nemotron-4-15b": (32, 6144, 48, 8, 24_576, 256_000),
    "mistral-nemo-12b": (40, 5120, 32, 8, 14_336, 131_072),
    "mamba2-130m": (24, 768, 0, 0, 0, 50_280),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
    "internvl2-26b": (48, 6144, 48, 8, 16_384, 92_553),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
}


@pytest.mark.parametrize("arch", sorted(EXPECT))
def test_exact_assignment_numbers(arch):
    cfg = get_config(arch)
    L, d, H, kv, ff, V = EXPECT[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff or (cfg.moe and cfg.moe.d_expert == ff)
    assert cfg.vocab == V


def test_moe_specs():
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.n_experts == 160 and ds.moe.top_k == 6
    assert ds.moe.n_shared == 2 and ds.mla.kv_lora == 512
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.moe.n_experts == 16 and phi.moe.top_k == 2
    jb = get_config("jamba-v0.1-52b")
    assert jb.moe.n_experts == 16 and jb.moe.top_k == 2
    assert jb.ssm.attn_period == 8          # 1:7 attn:mamba


def test_param_counts_near_published():
    """6 archs with verifiable totals: within 12 % of the nameplate."""
    from benchmarks.roofline import n_params
    expect = {"deepseek-v2-236b": 236e9, "phi3.5-moe-42b-a6.6b": 42e9,
              "gemma2-2b": 2.6e9, "mistral-nemo-12b": 12e9,
              "mamba2-130m": 0.13e9, "jamba-v0.1-52b": 52e9}
    for arch, n in expect.items():
        total, active = n_params(get_config(arch))
        assert abs(total - n) / n < 0.12, (arch, total)
        assert active <= total


def test_active_params_moe():
    from benchmarks.roofline import n_params
    total, active = n_params(get_config("phi3.5-moe-42b-a6.6b"))
    assert 5e9 < active < 9e9               # nameplate A6.6B


def test_layer_schedule_patterns():
    from repro.models.transformer import layer_schedule
    g = layer_schedule(get_config("gemma2-2b"))
    assert len(g) == 1 and len(g[0].pattern) == 2 and g[0].repeat == 13
    assert g[0].pattern[0].window == 4096 and g[0].pattern[1].window == 0
    j = layer_schedule(get_config("jamba-v0.1-52b"))
    assert len(j) == 1 and len(j[0].pattern) == 8 and j[0].repeat == 4
    mixers = [b.mixer for b in j[0].pattern]
    assert mixers.count("attn") == 1 and mixers[4] == "attn"
    ffns = [b.ffn for b in j[0].pattern]
    assert ffns.count("moe") == 4
    ds = layer_schedule(get_config("deepseek-v2-236b"))
    assert ds[0].pattern[0].ffn == "dense" and ds[0].repeat == 1
    assert ds[1].repeat == 59 and ds[1].pattern[0].ffn == "moe"


def test_long_500k_rule():
    runnable = [a for a in sorted(all_configs())
                if cell_supported(get_config(a), SHAPES["long_500k"])[0]]
    assert runnable == ["h2o-danube-1.8b", "jamba-v0.1-52b", "mamba2-130m"]


def test_swa_cache_is_window_bounded():
    from repro.serve.kv_cache import cache_bytes
    cfg = get_config("h2o-danube-1.8b")
    b_500k = cache_bytes(cfg, 1, 524_288, 16)
    b_32k = cache_bytes(cfg, 1, 32_768, 16)
    assert b_500k == b_32k                   # ring buffer = window size


def test_mla_cache_compression():
    """MLA latent cache must be ~an order smaller than GQA-equivalent."""
    from repro.serve.kv_cache import cache_bytes
    ds = get_config("deepseek-v2-236b")
    mn = get_config("mistral-nemo-12b")
    per_tok_ds = cache_bytes(ds, 1, 32_768, 16) / (60 * 32_768)
    per_tok_mn = cache_bytes(mn, 1, 32_768, 16) / (40 * 32_768)
    assert per_tok_ds < per_tok_mn
