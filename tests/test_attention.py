"""Chunked-attention (XLA path) correctness: fwd + custom-VJP bwd vs the
O(T²) reference. The hypothesis property tests on the block-pair
enumeration live in test_attention_props.py (skipped when hypothesis is
absent) so a missing dev dep can't error the whole module at collection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attend_chunked, reference_attention


@pytest.mark.parametrize("Tq,Tk,causal,window,qc,kc,soft", [
    (64, 64, True, 0, 16, 16, 0.0),
    (64, 64, True, 0, 16, 8, 50.0),
    (60, 60, True, 24, 16, 16, 0.0),      # non-multiple T + window
    (33, 128, False, 0, 16, 32, 0.0),     # cross attention
    (1, 64, True, 0, 8, 16, 0.0),         # decode-like
    (64, 128, True, 0, 16, 16, 0.0),      # q_offset continuation
])
def test_fwd_matches_reference(Tq, Tk, causal, window, qc, kc, soft, key):
    q = jax.random.normal(key, (2, Tq, 2, 3, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, Tk, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, Tk, 2, 8))
    qo = Tk - Tq if (Tq < Tk and causal) else 0
    out = attend_chunked(q, k, v, scale=0.3, causal=causal, window=window,
                         softcap=soft, q_chunk=qc, kv_chunk=kc, q_offset=qo)
    ref = reference_attention(q, k, v, scale=0.3, causal=causal,
                              window=window, softcap=soft, q_offset=qo)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-5)


@pytest.mark.parametrize("causal,window,soft", [(True, 0, 0.0),
                                                (True, 24, 50.0),
                                                (False, 0, 0.0)])
def test_custom_vjp_grads(causal, window, soft, key):
    q = jax.random.normal(key, (2, 48, 2, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 48, 2, 8))

    def fa(q, k, v):
        return (attend_chunked(q, k, v, scale=0.3, causal=causal,
                               window=window, softcap=soft, q_chunk=16,
                               kv_chunk=16) ** 2).sum()

    def fr(q, k, v):
        return (reference_attention(q, k, v, scale=0.3, causal=causal,
                                    window=window, softcap=soft) ** 2).sum()

    ga = jax.grad(fa, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, r in zip(ga, gr):
        np.testing.assert_allclose(np.array(a), np.array(r), atol=1e-3)


def test_traced_offset_matches_static(key):
    """CP path (_attend_scan, traced q_offset) ≡ custom-vjp static path."""
    q = jax.random.normal(key, (1, 32, 2, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 8))

    def traced(off):
        return attend_chunked(q, k, v, scale=0.3, causal=True, q_chunk=16,
                              kv_chunk=16, q_offset=off)

    out_t = jax.jit(traced)(jnp.int32(32))
    out_s = attend_chunked(q, k, v, scale=0.3, causal=True, q_chunk=16,
                           kv_chunk=16, q_offset=32)
    np.testing.assert_allclose(np.array(out_t), np.array(out_s), atol=2e-5)
