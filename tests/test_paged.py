"""Paged KV cache: allocator invariants, paged ↔ dense ↔ legacy token
equivalence (single-device and model-sharded pools), pool-exhaustion
admission backpressure, page reuse, and the fast path's
one-blocking-fetch-per-quantum contract."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import all_configs, smoke_config
from repro.serve import engine as engine_mod
from repro.serve.engine import (EngineStallError, PageAllocator,
                                PromptTooLongError, Request, make_engine)
from repro.serve.prefill import bucket_len


def _cfg(arch="mistral-nemo-12b"):
    return smoke_config(all_configs()[arch])


def _prompts(cfg, lens, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, n).tolist() for n in lens]


def _serve(cfg, ctx, prompts, max_new, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_quantum", 4)
    eng = make_engine(cfg, ctx, **kw)
    reqs = [Request(rid=i, prompt=p,
                    max_new=max_new[i] if isinstance(max_new, list)
                    else max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    return eng, reqs


# ------------------------------------------------------------- allocator
def test_allocator_free_list_and_trash_page():
    al = PageAllocator(num_pages=9, max_slots=2, pages_per_slot=8)
    assert al.usable_pages == 8
    al.commit(0, 5)
    al.grow_to(0, 2)
    assert al.count[0] == 2 and 0 not in al.table[0, :2]   # page 0 reserved
    assert al.outstanding() == 3
    assert al.can_commit(3) and not al.can_commit(4)
    with pytest.raises(RuntimeError):
        al.grow_to(0, 6)                    # beyond the committed budget
    with pytest.raises(RuntimeError):
        al.commit(0, 1)                     # slot already holds pages
    al.release(0)
    assert (al.table[0] == 0).all() and len(al.free) == 8
    assert al.can_commit(8)


def test_allocator_rejects_undersized_pool():
    with pytest.raises(ValueError):
        PageAllocator(num_pages=4, max_slots=1, pages_per_slot=4)


def test_engine_paged_config_validation(ctx):
    cfg = _cfg()
    with pytest.raises(ValueError):
        make_engine(cfg, ctx, paged=True, fast=False)
    with pytest.raises(ValueError):
        make_engine(cfg, ctx, max_len=64, paged=True, page_size=13)


# ------------------------------------------------- paged ↔ dense ↔ legacy
def test_paged_matches_fast_and_legacy(ctx):
    """Same workload through paged, dense-fast and legacy engines yields
    identical token streams, and every pool page is recycled at the end."""
    cfg = _cfg()
    prompts = _prompts(cfg, [4, 5, 9, 17, 18, 23, 60])
    max_new = [6, 1, 6, 6, 6, 6, 6]         # rid 1 finishes at prefill
    engp, paged = _serve(cfg, ctx, prompts, max_new, paged=True, page_size=8)
    _, fast = _serve(cfg, ctx, prompts, max_new)
    _, legacy = _serve(cfg, ctx, prompts, max_new, fast=False)
    for a, b, c in zip(paged, fast, legacy):
        assert a.done and a.out == b.out == c.out, (a.rid, a.out, c.out)
    assert len(engp.alloc.free) == engp.alloc.usable_pages
    assert (engp.alloc.table == 0).all() and engp.alloc.outstanding() == 0


def test_paged_mla_matches_legacy(ctx):
    """MLA pools (compressed-latent pages) decode token-identically."""
    cfg = _cfg("deepseek-v2-236b")
    prompts = _prompts(cfg, [5, 11, 19], seed=1)
    _, paged = _serve(cfg, ctx, prompts, 8, max_slots=2, paged=True,
                      page_size=8)
    _, legacy = _serve(cfg, ctx, prompts, 8, max_slots=2, fast=False)
    for a, b in zip(paged, legacy):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)


def test_paged_hybrid_rings_and_state_stay_dense(ctx):
    """Hybrid (jamba): mamba state and any ring layers keep dense layouts
    while attention layers page — streams still match the reference."""
    cfg = _cfg("jamba-v0.1-52b")
    prompts = _prompts(cfg, [5, 9], seed=1)
    engp, paged = _serve(cfg, ctx, prompts, 5, max_slots=2, max_len=48,
                         paged=True, page_size=8)
    assert engp.pad_safe is False           # exact-length prefill path
    _, legacy = _serve(cfg, ctx, prompts, 5, max_slots=2, max_len=48,
                       fast=False)
    for a, b in zip(paged, legacy):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)


def test_paged_long_decode_crosses_page_boundaries(ctx):
    """A short prompt decoding far past several page boundaries must lazily
    grow its page run and stay token-identical to the legacy engine."""
    cfg = _cfg()
    prompts = _prompts(cfg, [5], seed=7)
    engp, paged = _serve(cfg, ctx, prompts, 40, paged=True, page_size=8)
    _, legacy = _serve(cfg, ctx, prompts, 40, fast=False)
    assert paged[0].done and paged[0].out == legacy[0].out
    # context reached pos ≈ 5 + 40 → at least 5 eight-token pages were live
    peak_pages = engp.alloc.usable_pages - engp.alloc.min_free
    assert peak_pages >= 5, peak_pages
    assert len(engp.alloc.free) == engp.alloc.usable_pages


def test_paged_pool_exhaustion_backpressure(ctx):
    """A pool that fits one worst-case request forces serialized admission
    (backpressure, not a crash), recycles pages between requests, and still
    completes every stream identically to the legacy engine."""
    cfg = _cfg()
    prompts = _prompts(cfg, [5, 7, 9, 11], seed=5)
    # W(req) = ceil(min(5+60-1+4, 64)/16) = 4 pages = the whole usable pool
    engp, paged = _serve(cfg, ctx, prompts, 60, paged=True, page_size=16,
                         num_pages=5)
    _, legacy = _serve(cfg, ctx, prompts, 60, fast=False)
    for a, b in zip(paged, legacy):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)
    # never more than one request's pages live at once …
    assert engp.alloc.min_free >= 0
    assert all(c["admitted"] <= 1 for c in engp.cycle_log)
    # … so the four requests reused the same pages (page reuse evidence)
    assert engp.alloc.total_grants > engp.alloc.usable_pages
    assert len(engp.alloc.free) == engp.alloc.usable_pages


# model-sharded pool: exercises the msize>1 masked in-page-offset writes
# and the gpos page interleaving in _paged_write/ref._gathered, which the
# single-device tests shortcut past (8-device subprocess, cp_window style)
_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import all_configs, smoke_config
    from repro.serve.engine import Request, make_engine
    from repro.sharding.axes import ShardCtx

    cfg = smoke_config(all_configs()["mistral-nemo-12b"])
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (5, 11, 19)]

    def serve(ctx, **kw):
        eng = make_engine(cfg, ctx, max_slots=2, max_len=64,
                          decode_quantum=4, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=12)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        return reqs

    # reference is the DENSE fast engine on the SAME mesh: sharded bf16
    # reductions already reorder vs 1-device (greedy argmax amplifies
    # that, dense path included), so the paging invariant is paged ≡
    # dense at identical sharding
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    ref = serve(ShardCtx(mesh=mesh))
    # 4-way model axis: page_size 8 → each shard owns 2 offsets per page
    got = serve(ShardCtx(mesh=mesh), paged=True, page_size=8)
    for a, b in zip(got, ref):
        assert a.done and a.out == b.out, (a.rid, a.out, b.out)
    print("PAGED-SHARD-OK")
""")


@pytest.mark.slow
def test_paged_model_sharded_matches_reference():
    r = subprocess.run([sys.executable, "-c", _SHARDED],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PAGED-SHARD-OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.slow
def test_paged_rejects_data_parallel_mesh():
    """Pool pages are replicated over the batch axes — the engine must
    refuse rather than let replicas diverge (ROADMAP follow-on)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import all_configs, smoke_config
        from repro.serve.engine import make_engine
        from repro.sharding.axes import ShardCtx
        cfg = smoke_config(all_configs()["mistral-nemo-12b"])
        ctx = ShardCtx(mesh=jax.make_mesh((2, 4), ("data", "model")))
        try:
            make_engine(cfg, ctx, max_len=64, paged=True, page_size=8)
        except ValueError as e:
            assert "batch axis" in str(e), e
            print("PAGED-DP-REJECT-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ, PYTHONPATH="src"),
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PAGED-DP-REJECT-OK" in r.stdout, (r.stdout[-2000:]
                                              + r.stderr[-2000:])


# ------------------------------------------------------- host-sync probe
@pytest.mark.parametrize("kw", [{}, {"paged": True, "page_size": 8},
                                {"temperature": 0.8, "top_k": 4}],
                         ids=["dense", "paged", "sampled"])
def test_single_host_fetch_per_quantum(ctx, monkeypatch, kw):
    """The fast path performs exactly ONE blocking device→host fetch per
    decode quantum (plus one per admitted prefill group) — including under
    paged decode and on-device sampling (PRNG key stays device-resident)."""
    cfg = _cfg()
    calls = {"n": 0}
    orig = engine_mod._host_fetch

    def probe(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(engine_mod, "_host_fetch", probe)
    eng, reqs = _serve(cfg, ctx, _prompts(cfg, [4, 9, 17]), 8, **kw)
    assert all(r.done for r in reqs)
    assert eng.quanta > 0 and eng.prefill_groups > 0
    assert calls["n"] == eng.quanta + eng.prefill_groups, (
        calls["n"], eng.quanta, eng.prefill_groups)


# ------------------------------------------------ graceful prompt limits
def test_submit_rejects_oversized_and_empty_prompts(ctx):
    cfg = _cfg()
    eng = make_engine(cfg, ctx, max_slots=2, max_len=32)
    with pytest.raises(PromptTooLongError):
        eng.submit(Request(rid=0, prompt=list(range(32)), max_new=4))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=1, prompt=[], max_new=4))
    assert not eng.pending                  # rejected requests never queue


def test_bucket_len_typed_error():
    assert bucket_len(17, min_bucket=16, max_bucket=64) == 32
    with pytest.raises(ValueError):
        bucket_len(100, min_bucket=16, max_bucket=64)


# ----------------------------------------------------------- stall guard
def test_run_guard_is_proportional_and_loud(ctx):
    cfg = _cfg()
    eng = make_engine(cfg, ctx, max_slots=2, max_len=32, decode_quantum=4)
    reqs = [Request(rid=i, prompt=[1, 2, 3], max_new=8) for i in range(3)]
    for r in reqs:
        eng.pending.append(r)
    small = eng._guard_limit()
    eng.pending.extend(Request(rid=9 + i, prompt=[1], max_new=800)
                       for i in range(5))
    assert eng._guard_limit() > small       # scales with outstanding work
    eng.pending.clear()
    eng.step = lambda: None                 # simulate a scheduling bug
    with pytest.raises(EngineStallError):
        eng.run([Request(rid=99, prompt=[1, 2], max_new=4)])
