"""Benchmark driver — one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
validates the paper's claims (§6: 25–50 % heterogeneous time reduction,
energy neutrality; §5: ~8× platform gap at 16 M elements). Also writes
``BENCH_1.json`` (serving tokens/sec + speedup) so the perf trajectory
accumulates across PRs.
"""
from __future__ import annotations

import time


def _timeit(fn, *args, reps: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps * 1e6, out


def main() -> None:
    print("name,us_per_call,derived")

    # --- Fig. 5: scheduler perf vs chunk size, CC/FC configs -------------
    from benchmarks.bench_scheduler import rows as sched_rows
    us, rows = _timeit(sched_rows, 6_000)
    best = {}
    for r in rows:
        key = (r["platform"], r["ncc"], r["nfc"])
        best[key] = max(best.get(key, 0.0), r["it_per_s"])
    for (plat, ncc, nfc), v in sorted(best.items()):
        print(f"fig5/{plat}/cc{ncc}_fc{nfc},{us:.0f},{v:.0f}")
    # paper §6 claim: heterogeneous reduces execution time 25–50 %
    for plat in ("zynq-z7020", "zynq-ultrascale-zu9"):
        cfgs = {k[1:]: v for k, v in best.items() if k[0] == plat}
        ncc = max(k[0] for k in cfgs)
        nfc = max(k[1] for k in cfgs)
        het = cfgs[(ncc, nfc)]
        off = cfgs[(0, nfc)]
        reduction = 1.0 - off / het
        print(f"fig5/{plat}/het_time_reduction,{us:.0f},{reduction:.3f}")

    # --- Fig. 6: power & energy ------------------------------------------
    from benchmarks.bench_energy import rows as energy_rows
    us, erows = _timeit(energy_rows, 6_000)
    for r in erows:
        print(f"fig6/{r['platform']}/speedup,{us:.0f},{r['speedup']:.3f}")
        print(f"fig6/{r['platform']}/energy_ratio,{us:.0f},"
              f"{r['energy_ratio']:.3f}")

    # --- Table 2: GEMM kernel block sweep ---------------------------------
    from benchmarks.bench_gemm import sweep
    us, grows = _timeit(sweep, 256)
    for r in grows:
        print(f"table2/gemm_bn{r['bn']}/vmem_frac,{r['time_s']*1e6:.0f},"
              f"{r['vmem_frac']:.4f}")

    # --- §5: 16 M scaling study -------------------------------------------
    from benchmarks.bench_scaling import rows as scaling_rows
    us, srows = _timeit(scaling_rows)
    for r in srows:
        print(f"scaling/{r['size']}/ultra_over_zynq,{us:.0f},"
              f"{r['ultra_over_zynq']:.2f}")

    # --- Serving fast path + paged KV cache (PR 1 / PR 2) -----------------
    try:
        from benchmarks.bench_serve import (csv_rows, paged_rows,
                                            rows as serve_rows,
                                            write_bench_json)
        srows = serve_rows()
        try:
            mem = paged_rows()
        except Exception as e:  # keep the PR-1 serve baseline either way
            mem = None
            print(f"serve/paged_unavailable,0,0  # {e}")
        for line in csv_rows(srows, mem):
            print(line)
        write_bench_json(srows, mem)
    except Exception as e:  # serving bench must not sink the driver
        print(f"serve/unavailable,0,0  # {e}")

    # --- Paged-attention kernel + long-context point (PR 3) ---------------
    try:
        from benchmarks.bench_serve import (kernel_csv_rows, kernel_rows,
                                            long_ctx_row, write_bench2_json)
        kern = kernel_rows()
        long_row = long_ctx_row()
        for line in kernel_csv_rows(kern, long_row):
            print(line)
        write_bench2_json(kern, long_row)
    except Exception as e:  # kernel bench must not sink the driver
        print(f"serve/paged_kernel_unavailable,0,0  # {e}")

    # --- Multi-engine heterogeneous tier pool (PR 4) -----------------------
    try:
        from benchmarks.bench_serve import (multi_csv_rows, multi_tier_rows,
                                            write_bench3_json)
        mt = multi_tier_rows()
        for line in multi_csv_rows(mt):
            print(line)
        write_bench3_json(mt)
    except Exception as e:  # multi-tier bench must not sink the driver
        print(f"serve/multi_tier_unavailable,0,0  # {e}")

    # --- Speculative big/little decode (PR 5) ------------------------------
    try:
        from benchmarks.bench_serve import (spec_csv_rows, spec_decode_rows,
                                            write_bench4_json)
        sp = spec_decode_rows()
        for line in spec_csv_rows(sp):
            print(line)
        write_bench4_json(sp)
    except Exception as e:  # spec bench must not sink the driver
        print(f"serve/spec_decode_unavailable,0,0  # {e}")

    # --- Degraded-mode fault-tolerant pool (PR 6) --------------------------
    try:
        from benchmarks.bench_serve import (fault_csv_rows, fault_rows,
                                            write_bench5_json)
        ft = fault_rows()
        for line in fault_csv_rows(ft):
            print(line)
        write_bench5_json(ft)
    except Exception as e:  # fault bench must not sink the driver
        print(f"serve/fault_tolerance_unavailable,0,0  # {e}")

    # --- Roofline summary (from dry-run artifacts, if present) ------------
    try:
        from benchmarks.roofline import load_cells, roofline_fraction
        cells = load_cells()
        if cells:
            singles = [c for c in cells if c.mesh == "single"]
            for c in sorted(singles, key=roofline_fraction)[:3]:
                print(f"roofline/worst/{c.arch}__{c.shape},0,"
                      f"{roofline_fraction(c):.4f}")
            frac = sum(roofline_fraction(c) for c in singles) / len(singles)
            print(f"roofline/mean_fraction_single_pod,0,{frac:.4f}")
    except Exception as e:  # dry-run artifacts absent
        print(f"roofline/unavailable,0,0  # {e}")


if __name__ == "__main__":
    main()
