"""Fig. 6 reproduction: power & energy across CC/FC configurations.

Claim under test (paper §6): heterogeneous configs are ~energy-neutral —
the added CPU power is offset by the shorter runtime — while being the
fastest. We verify energy(het) / energy(offload-only) ∈ [0.8, 1.3] and
t(het) < t(offload-only) on both platform models."""
from __future__ import annotations

from repro.configs.gemm_paper import PLATFORMS
from benchmarks.bench_scheduler import run_config


def rows(n: int = 20_000):
    out = []
    for pname, plat in PLATFORMS.items():
        base = run_config(plat, 0, plat.n_fpga_units, 64, n)
        het = run_config(plat, plat.n_cpu_cores, plat.n_fpga_units, 64, n)
        out.append({
            "platform": pname,
            "t_offload": base["wall_s"], "t_het": het["wall_s"],
            "speedup": base["wall_s"] / het["wall_s"],
            "e_offload": base["energy_J"], "e_het": het["energy_J"],
            "energy_ratio": het["energy_J"] / base["energy_J"],
            "p_offload": base["power_W"], "p_het": het["power_W"],
        })
    return out


def main():
    print("platform,t_offload,t_het,speedup,e_offload,e_het,energy_ratio,"
          "p_offload,p_het")
    for r in rows():
        print(f"{r['platform']},{r['t_offload']:.3f},{r['t_het']:.3f},"
              f"{r['speedup']:.3f},{r['e_offload']:.3f},{r['e_het']:.3f},"
              f"{r['energy_ratio']:.3f},{r['p_offload']:.3f},"
              f"{r['p_het']:.3f}")


if __name__ == "__main__":
    main()
