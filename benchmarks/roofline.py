"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell:
  compute term    = dot_FLOPs / (chips × 197 TF/s)      [loop-aware HLO]
  memory term     = HBM bytes / (chips × 819 GB/s)      [analytic: weights
                    read + cache traffic + activation IO per step]
  collective term = wire bytes / (chips × 50 GB/s)      [loop-aware HLO]

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-flops
ratio MODEL_FLOPS / HLO_FLOPs. The dominant term is the bottleneck the
§Perf loop iterates on.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

import numpy as np

from repro.configs import SHAPES, all_configs, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.serve.kv_cache import cache_bytes
from repro.sharding import params as prm

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "../experiments/dryrun")


# ------------------------------------------------------------ model flops
def n_params(cfg: ModelConfig) -> tuple[int, int]:
    """→ (total, active) parameter counts."""
    from repro.models.model import model_defs
    total = prm.n_params(model_defs(cfg))
    active = total
    if cfg.moe:
        m = cfg.moe
        per_exp = 3 * cfg.d_model * m.d_expert if cfg.act in ("swiglu", "geglu") \
            else 2 * cfg.d_model * m.d_expert
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
        active = total - n_moe * (m.n_experts - m.top_k) * per_exp
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·D for train; 2·N_active·D for inference steps."""
    total, active = n_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * (shape.seq_len if not cfg.enc_dec
                                       else shape.seq_len + cfg.max_decoder_len)
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch          # decode: 1 token/seq


# ------------------------------------------------------- analytic HBM bytes
def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeSpec,
                         n_dev: int, msize: int = 16) -> float:
    """Dominant HBM traffic per device per step: parameter reads (sharded)
    + optimizer state R/W (train) + KV-cache read (decode) + activation IO
    (2 bytes·tokens·d_model·layers·~8 tensors)."""
    from repro.models.model import model_defs
    pbytes = prm.param_bytes(model_defs(cfg)) / n_dev
    tokens_local = shape.global_batch * max(shape.seq_len, 1) / n_dev
    if shape.kind == "train":
        opt = 2 * pbytes * 2            # m, v read+write (≥bf16)
        act = tokens_local * cfg.d_model * 2 * cfg.n_layers * 8 * msize
        # ×msize: tokens are gathered over the model axis inside blocks
        return 3 * pbytes + opt + act   # params read fwd+bwd+update
    if shape.kind == "prefill":
        act = tokens_local * cfg.d_model * 2 * cfg.n_layers * 6 * msize
        return pbytes + act
    cache = cache_bytes(cfg, shape.global_batch, shape.seq_len, msize) / n_dev
    return pbytes + cache               # decode: weights + full cache read


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    peak_gib: float
    fits: bool
    note: str = ""


def analyze_cell(rec: dict) -> Cell | None:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    msize = 16
    dot_flops_dev = rec["hlo"]["dot_flops_per_device"]
    # bf16-normalized wire bytes (CPU XLA legalizes bf16 dots to f32 and
    # hoists converts across collectives; TPU keeps bf16 — see hlo_analysis)
    coll_dev = rec["hlo"].get("collective_bytes_per_device_bf16norm",
                              rec["hlo"]["collective_bytes_per_device"])
    compute_s = dot_flops_dev / PEAK_FLOPS_BF16
    hbm = hbm_bytes_per_device(cfg, shape, n_dev, msize)
    memory_s = hbm / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = dot_flops_dev * n_dev
    return Cell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        peak_gib=rec["memory"]["peak_bytes_per_device"] / 2**30,
        fits=rec["memory"]["fits_hbm"],
    )


def load_cells(dryrun_dir: str = DRYRUN_DIR) -> list[Cell]:
    cells = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        c = analyze_cell(json.load(open(f)))
        if c:
            cells.append(c)
    return cells


def roofline_fraction(c: Cell) -> float:
    """Achievable MFU bound = useful compute / dominant-term time."""
    step_time = max(c.compute_s, c.memory_s, c.collective_s)
    ideal = c.model_flops / (PEAK_FLOPS_BF16 * _ndev(c))
    return ideal / step_time if step_time else 0.0


def _ndev(c: Cell) -> int:
    return 512 if c.mesh == "multi" else 256


def table(cells: list[Cell], mesh: str = "single") -> str:
    rows = [c for c in cells if c.mesh == mesh]
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | peak GiB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(rows, key=lambda c: (c.arch, c.shape)):
        out.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} "
            f"| {c.collective_s:.3e} | **{c.dominant}** "
            f"| {c.useful_ratio:.2f} | {roofline_fraction(c):.3f} "
            f"| {c.peak_gib:.1f} | {'y' if c.fits else 'N'} |")
    return "\n".join(out)


def main() -> None:
    cells = load_cells()
    print(table(cells, "single"))
    print()
    worst = sorted((c for c in cells if c.mesh == "single"),
                   key=roofline_fraction)[:5]
    print("worst roofline fractions:")
    for c in worst:
        print(f"  {c.arch} {c.shape}: frac={roofline_fraction(c):.4f} "
              f"dominant={c.dominant}")
    coll = sorted((c for c in cells if c.mesh == "single"),
                  key=lambda c: -c.collective_s / max(c.compute_s, 1e-12))[:5]
    print("most collective-bound:")
    for c in coll:
        print(f"  {c.arch} {c.shape}: coll/compute="
              f"{c.collective_s / max(c.compute_s, 1e-12):.2f}")


if __name__ == "__main__":
    main()
