"""Fig. 5 reproduction: performance vs FPGA chunk size for CC/FC configs.

Resources are calibrated simulators (service rates from the paper's observed
platform ratio) plus a *real-executor* mode (jitted matmul = accelerator
class, per-row numpy = core class) used by examples/hetero_gemm.py. Reports
iterations/second per (config × chunk) — the U-shaped chunk-size curve and
the heterogeneous win are the paper's headline results.
"""
from __future__ import annotations

import time

from repro.configs.gemm_paper import FPGA_CHUNK_SWEEP, PLATFORMS
from repro.core.energy import POWER_MODELS, run_energy
from repro.core.hbb import Body, Dynamic, Params


class CalibratedBody(Body):
    """Service times calibrated to a platform's relative speed f."""

    def __init__(self, cpu_it_s: float, fpga_it_s: float):
        self.cpu_s = 1.0 / cpu_it_s
        self.fpga_s = 1.0 / fpga_it_s

    def operatorCPU(self, b, e):
        time.sleep((e - b) * self.cpu_s)

    def operatorFPGA(self, b, e):
        time.sleep((e - b) * self.fpga_s)


def run_config(platform, ncc: int, nfc: int, chunk: int, n: int = 20_000):
    body = CalibratedBody(cpu_it_s=5_000.0 * platform.cpu_freq_mhz / 600.0,
                          fpga_it_s=5_000.0 * platform.rel_fpga_speed
                          * platform.cpu_freq_mhz / 600.0)
    p = Params(num_cpu_tokens=ncc, num_fpga_tokens=nfc, fpga_chunk=chunk,
               f0=platform.rel_fpga_speed)
    rep = Dynamic(p).parallel_for(0, n, body)
    kinds = {f"FC{i}": "accelerator" for i in range(nfc)}
    kinds.update({f"CC{i}": "core" for i in range(ncc)})
    pm = POWER_MODELS[platform.name]
    energy, power = run_energy(rep, kinds, pm)
    return {"it_per_s": n / rep.wall_time, "wall_s": rep.wall_time,
            "f": rep.f_final, "energy_J": energy, "power_W": power}


def rows(n: int = 20_000):
    out = []
    for pname, plat in PLATFORMS.items():
        configs = [(plat.n_cpu_cores, 0), (0, plat.n_fpga_units),
                   (plat.n_cpu_cores, plat.n_fpga_units)]
        for ncc, nfc in configs:
            for chunk in FPGA_CHUNK_SWEEP:
                if nfc == 0 and chunk != FPGA_CHUNK_SWEEP[0]:
                    continue        # chunk sweep is an FPGA knob
                r = run_config(plat, ncc, nfc, chunk, n)
                out.append({"platform": pname, "ncc": ncc, "nfc": nfc,
                            "chunk": chunk, **r})
    return out


def main():
    print("platform,ncc,nfc,chunk,it_per_s,wall_s,f,energy_J,power_W")
    for r in rows():
        print(f"{r['platform']},{r['ncc']},{r['nfc']},{r['chunk']},"
              f"{r['it_per_s']:.0f},{r['wall_s']:.3f},{r['f']:.2f},"
              f"{r['energy_J']:.3f},{r['power_W']:.3f}")


if __name__ == "__main__":
    main()
