"""§5 final paragraph reproduction: the 16 M-element scaling study.

The paper: going 1 M → 16 M elements, the small platform (Zynq) collapses
(500 K → 50 K elements/s, memory-bound) while the larger ZynqUS+ sustains
400 K (8× higher). We model the collapse with each platform's effective
memory-traffic budget and verify the ~8× platform gap at 16 M."""
from __future__ import annotations

import time

from repro.configs.gemm_paper import GEMM_N_MAIN, GEMM_N_SCALING, PLATFORMS
from repro.core.hbb import Body, Dynamic, Params


class ScalingBody(Body):
    """Service time grows superlinearly once the working set exceeds the
    platform's on-chip capacity (columns buffered → extra DRAM traffic)."""

    def __init__(self, plat, n: int):
        spill = max(1.0, n / (plat.buffered_columns * 64)) ** 0.5
        base = 1.0 / (5_000.0 * plat.cpu_freq_mhz / 600.0)
        self.cpu_s = base * spill
        self.fpga_s = base / plat.rel_fpga_speed * spill

    def operatorCPU(self, b, e):
        time.sleep((e - b) * self.cpu_s)

    def operatorFPGA(self, b, e):
        time.sleep((e - b) * self.fpga_s)


def run(plat, n_matrix: int, iters: int = 8_000):
    body = ScalingBody(plat, n_matrix)
    p = Params(num_cpu_tokens=plat.n_cpu_cores,
               num_fpga_tokens=plat.n_fpga_units, fpga_chunk=64,
               f0=plat.rel_fpga_speed)
    rep = Dynamic(p).parallel_for(0, iters, body)
    return iters / rep.wall_time


def rows():
    out = []
    for size_name, n in (("1M", GEMM_N_MAIN), ("16M", GEMM_N_SCALING)):
        rates = {}
        for pname, plat in PLATFORMS.items():
            rates[pname] = run(plat, n)
        out.append({"size": size_name, **rates,
                    "ultra_over_zynq":
                        rates["zynq-ultrascale-zu9"] / rates["zynq-z7020"]})
    return out


def main():
    print("size,zynq_it_s,ultra_it_s,ultra_over_zynq")
    for r in rows():
        print(f"{r['size']},{r['zynq-z7020']:.0f},"
              f"{r['zynq-ultrascale-zu9']:.0f},{r['ultra_over_zynq']:.2f}")


if __name__ == "__main__":
    main()
