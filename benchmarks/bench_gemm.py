"""Table 2 analogue: GEMM kernel block-shape ("buffered columns") sweep.

The paper's capacity knob (32 columns on Zynq / 128 on ZynqUS+, bounded by
BRAM) becomes the Pallas bn block dimension bounded by VMEM; we report the
VMEM working set and measured time per block shape (CPU interpret-mode
times are *correctness-path* numbers; the VMEM model is the TPU-relevant
output)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.gemm.gemm import gemm, vmem_bytes
from repro.kernels.gemm.ref import gemm_ref
from repro.launch.mesh import VMEM_BYTES


def sweep(n: int = 512):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    ref = gemm_ref(a, b)
    rows = []
    for bn in (32, 64, 128, 256):
        bm, bk = min(128, n), min(256, n)
        vb = vmem_bytes(bm, bn, bk)
        t0 = time.perf_counter()
        out = gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - ref)))
        rows.append({"bn": bn, "vmem_bytes": vb,
                     "vmem_frac": vb / VMEM_BYTES, "time_s": dt,
                     "max_err": err, "fits_vmem": vb < VMEM_BYTES})
    return rows


def main():
    print("bn,vmem_bytes,vmem_frac,fits_vmem,time_s,max_err")
    for r in sweep():
        print(f"{r['bn']},{r['vmem_bytes']},{r['vmem_frac']:.4f},"
              f"{r['fits_vmem']},{r['time_s']:.3f},{r['max_err']:.2e}")


if __name__ == "__main__":
    main()
