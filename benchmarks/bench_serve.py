"""Serving fast-path benchmark: fused quantum decode + bucketed batched
prefill + cache donation vs. the reference per-token engine.

    PYTHONPATH=src python -m benchmarks.bench_serve

Measures, on the SAME workload (mixed prompt lengths so the legacy path
recompiles per length):
  * tokens/sec end-to-end (compiles included — recompile overhead is the
    point) for fast and legacy engines, and their ratio;
  * prefill compile count (jit cache probe): fast = one per length bucket,
    legacy = one per distinct prompt length;
  * per-cycle scheduler balance: mean admitted prompts vs. decoded tokens
    per engine cycle and the final HBB `f` ratio.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    # many distinct lengths across two power-of-2 buckets (≤16, ≤32)
    lens = [int(x) for x in rng.integers(4, 31, n_requests)]
    return [(i, rng.integers(0, cfg.vocab, n).tolist()) for i, n in
            enumerate(lens)]


def serve_once(fast: bool, *, arch: str = "h2o-danube-1.8b",
               n_requests: int = 12, max_new: int = 16,
               decode_quantum: int = 8, seed: int = 0) -> dict:
    from repro.configs import get_config, smoke_config
    from repro.serve.engine import Request, make_engine
    from repro.sharding.axes import single_device_ctx

    cfg = smoke_config(get_config(arch))
    ctx = single_device_ctx()
    eng = make_engine(cfg, ctx, max_slots=4, max_len=64, fast=fast,
                      decode_quantum=decode_quantum)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in _workload(cfg, n_requests, max_new, seed)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in reqs)
    cycles = eng.cycle_log or [{"admitted": 0, "decoded": 0, "f": 0.0}]
    return {
        "mode": "fast" if fast else "legacy",
        "tok": tok,
        "dt": dt,
        "tok_s": tok / dt,
        "prefill_compiles": eng.prefill_compiles(),
        "distinct_prompt_lens": len({len(r.prompt) for r in reqs}),
        "f": eng.tracker.f(),
        "mean_admitted_per_cycle": float(np.mean([c["admitted"]
                                                  for c in cycles])),
        "mean_decoded_per_cycle": float(np.mean([c["decoded"]
                                                 for c in cycles])),
        "cycles": len(cycles),
        "all_done": all(r.done for r in reqs),
    }


def rows(**kw) -> list[dict]:
    fast = serve_once(True, **kw)
    legacy = serve_once(False, **kw)
    fast["speedup_vs_legacy"] = fast["tok_s"] / max(legacy["tok_s"], 1e-9)
    legacy["speedup_vs_legacy"] = 1.0
    return [fast, legacy]


def csv_rows(out: list[dict]) -> list[str]:
    """Harness-contract ``name,us_per_call,derived`` rows (shared with
    benchmarks/run.py so the two emitters can't drift)."""
    lines = []
    for r in out:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{r['mode']}/tok_s,{us:.0f},{r['tok_s']:.1f}")
        lines.append(f"serve/{r['mode']}/prefill_compiles,{us:.0f},"
                     f"{r['prefill_compiles']}")
    lines.append(f"serve/speedup_fast_over_legacy,0,"
                 f"{out[0]['speedup_vs_legacy']:.2f}")
    return lines


def write_bench_json(out: list[dict],
                     path: str | Path = "BENCH_1.json") -> None:
    """The per-PR perf artifact — one writer, shared by main(), run.py, CI."""
    fast, legacy = out
    Path(path).write_text(json.dumps({
        "bench": "serve_fast_path",
        "arch": "h2o-danube-1.8b (smoke)",
        "serve_tok_s": fast["tok_s"],
        "serve_tok_s_legacy": legacy["tok_s"],
        "speedup_fast_over_legacy": fast["speedup_vs_legacy"],
        "prefill_compiles_fast": fast["prefill_compiles"],
        "prefill_compiles_legacy": legacy["prefill_compiles"],
        "distinct_prompt_lens": fast["distinct_prompt_lens"],
        "f_ratio": fast["f"],
    }, indent=2) + "\n")


def main() -> None:
    out = rows()
    fast, legacy = out
    print("name,us_per_call,derived")
    for line in csv_rows(out):
        print(line)
    write_bench_json(out)
    print(f"# fast: {fast['tok']} tok in {fast['dt']:.2f}s "
          f"({fast['tok_s']:.1f} tok/s), {fast['prefill_compiles']} prefill "
          f"compiles for {fast['distinct_prompt_lens']} distinct lengths, "
          f"f={fast['f']:.2f}, balance {fast['mean_admitted_per_cycle']:.2f} "
          f"admits / {fast['mean_decoded_per_cycle']:.1f} decodes per cycle")
    print(f"# legacy: {legacy['tok']} tok in {legacy['dt']:.2f}s "
          f"({legacy['tok_s']:.1f} tok/s), {legacy['prefill_compiles']} "
          f"prefill compiles")
    assert fast["all_done"] and legacy["all_done"]


if __name__ == "__main__":
    main()
