"""Serving fast-path benchmark: fused quantum decode + bucketed batched
prefill + cache donation vs. the reference per-token engine, plus the paged
KV cache vs. dense per-slot rows.

    PYTHONPATH=src python -m benchmarks.bench_serve

Measures, on the SAME workload (mixed prompt lengths so the legacy path
recompiles per length):
  * tokens/sec end-to-end (compiles included — recompile overhead is the
    point) for fast and legacy engines, and their ratio;
  * prefill compile count (jit cache probe): fast = one per length bucket,
    legacy = one per distinct prompt length;
  * per-cycle scheduler balance: mean admitted prompts vs. decoded tokens
    per engine cycle and the final HBB `f` ratio;
  * memory: reserved KV-cache bytes (paged pool vs dense rows, sized for
    the same workload) and the max context a single request could grow to
    inside the dense engine's HBM budget.

The paged-vs-dense comparison runs on a full-attention arch (mistral-nemo)
— sliding-window archs keep their O(window) rings and would not exercise
the pool.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

MAX_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 8


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    # many distinct lengths across two power-of-2 buckets (≤16, ≤32)
    lens = [int(x) for x in rng.integers(4, 31, n_requests)]
    return [(i, rng.integers(0, cfg.vocab, n).tolist()) for i, n in
            enumerate(lens)]


def _workload_pool_pages(workload, max_new: int, decode_quantum: int) -> int:
    """Pool sized to the workload's worst case (+ the reserved trash page)
    instead of max_slots × max_len — the memory the paged engine banks."""
    from repro.serve.engine import worst_case_pages

    max_prompt = max(len(p) for _, p in workload)
    return 1 + MAX_SLOTS * worst_case_pages(max_prompt, max_new,
                                            decode_quantum, MAX_LEN,
                                            PAGE_SIZE)


def serve_once(mode: str, *, arch: str = "h2o-danube-1.8b",
               n_requests: int = 12, max_new: int = 16,
               decode_quantum: int = 8, seed: int = 0,
               warmup: bool = False, reps: int = 1) -> dict:
    """mode: "fast" | "legacy" | "paged". `warmup` pre-runs a small workload
    so the timed pass measures steady state (used for the paged-vs-dense
    memory comparison, where compile counts are identical by construction
    and the interesting number is the per-token cost of page indirection);
    `reps` re-runs the timed workload and keeps the fastest pass (host
    scheduling noise dwarfs the per-token delta on CPU smoke)."""
    from repro.configs import get_config, smoke_config
    from repro.serve.engine import Request, make_engine
    from repro.sharding.axes import single_device_ctx

    cfg = smoke_config(get_config(arch))
    ctx = single_device_ctx()
    work = _workload(cfg, n_requests, max_new, seed)
    warm_work = _workload(cfg, 4, max_new, seed + 1) if warmup else []
    kw = {}
    if mode == "paged":
        # size for the timed workload AND the (slightly longer) warmup pass
        kw = dict(paged=True, page_size=PAGE_SIZE,
                  num_pages=_workload_pool_pages(work + warm_work,
                                                 max_new + 1, decode_quantum))
    eng = make_engine(cfg, ctx, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                      fast=mode != "legacy", decode_quantum=decode_quantum,
                      **kw)
    if warmup:
        eng.run([Request(rid=-1 - i, prompt=p, max_new=max_new + 1)
                 for i, p in warm_work])
    dt = float("inf")
    for rep in range(max(1, reps)):
        reqs = [Request(rid=1000 * rep + i, prompt=p, max_new=max_new)
                for i, p in work]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = min(dt, time.perf_counter() - t0)
    tok = sum(len(r.out) for r in reqs)
    cycles = eng.cycle_log or [{"admitted": 0, "decoded": 0, "f": 0.0}]
    return {
        "mode": mode,
        "arch": arch,
        "tok": tok,
        "dt": dt,
        "tok_s": tok / dt,
        "prefill_compiles": eng.prefill_compiles(),
        "distinct_prompt_lens": len({len(r.prompt) for r in reqs}),
        "f": eng.tracker.f(),
        "reserved_cache_bytes": eng.reserved_cache_bytes(),
        "mean_admitted_per_cycle": float(np.mean([c["admitted"]
                                                  for c in cycles])),
        "mean_decoded_per_cycle": float(np.mean([c["decoded"]
                                                 for c in cycles])),
        "cycles": len(cycles),
        "all_done": all(r.done for r in reqs),
    }


def paged_rows(**kw) -> list[dict]:
    """Dense-fast vs paged on a full-attention arch, with memory columns."""
    from repro.configs import get_config, smoke_config
    from repro.serve.kv_cache import page_bytes

    kw.setdefault("arch", "mistral-nemo-12b")
    kw.setdefault("warmup", True)
    kw.setdefault("reps", 3)
    dense = serve_once("fast", **kw)
    paged = serve_once("paged", **kw)
    paged["tok_s_vs_dense"] = paged["tok_s"] / max(dense["tok_s"], 1e-9)
    cfg = smoke_config(get_config(kw["arch"]))
    # longest context one request could occupy inside the DENSE engine's
    # cache budget, were it granted every page (page-table width permitting)
    per_page = max(1, page_bytes(cfg, PAGE_SIZE))
    paged["max_ctx_at_dense_hbm"] = (
        (dense["reserved_cache_bytes"] // per_page - 1) * PAGE_SIZE)
    dense["max_ctx_at_dense_hbm"] = MAX_LEN      # one dense row, fixed
    return [dense, paged]


def rows(**kw) -> list[dict]:
    fast = serve_once("fast", **kw)
    legacy = serve_once("legacy", **kw)
    fast["speedup_vs_legacy"] = fast["tok_s"] / max(legacy["tok_s"], 1e-9)
    legacy["speedup_vs_legacy"] = 1.0
    return [fast, legacy]


def csv_rows(out: list[dict], mem: list[dict] | None) -> list[str]:
    """Harness-contract ``name,us_per_call,derived`` rows (shared with
    benchmarks/run.py so the two emitters can't drift). `mem` is None when
    the paged comparison is unavailable."""
    lines = []
    for r in out:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{r['mode']}/tok_s,{us:.0f},{r['tok_s']:.1f}")
        lines.append(f"serve/{r['mode']}/prefill_compiles,{us:.0f},"
                     f"{r['prefill_compiles']}")
    lines.append(f"serve/speedup_fast_over_legacy,0,"
                 f"{out[0]['speedup_vs_legacy']:.2f}")
    for r in mem or []:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/mem/{r['mode']}/reserved_cache_kb,{us:.0f},"
                     f"{r['reserved_cache_bytes'] / 1024:.1f}")
        lines.append(f"serve/mem/{r['mode']}/max_ctx_at_dense_hbm,{us:.0f},"
                     f"{r['max_ctx_at_dense_hbm']}")
    if mem:
        lines.append(f"serve/mem/paged_tok_s_vs_dense,0,"
                     f"{mem[1]['tok_s_vs_dense']:.2f}")
    return lines


def write_bench_json(out: list[dict], mem: list[dict] | None,
                     path: str | Path = "BENCH_1.json") -> None:
    """The per-PR perf artifact — one writer, shared by main(), run.py, CI."""
    fast, legacy = out
    doc = {
        "bench": "serve_fast_path",
        "arch": "h2o-danube-1.8b (smoke)",
        "serve_tok_s": fast["tok_s"],
        "serve_tok_s_legacy": legacy["tok_s"],
        "speedup_fast_over_legacy": fast["speedup_vs_legacy"],
        "prefill_compiles_fast": fast["prefill_compiles"],
        "prefill_compiles_legacy": legacy["prefill_compiles"],
        "distinct_prompt_lens": fast["distinct_prompt_lens"],
        "f_ratio": fast["f"],
    }
    if mem:
        dense, paged = mem
        doc.update({
            "paged_arch": paged["arch"] + " (smoke)",
            "paged_tok_s": paged["tok_s"],
            "paged_tok_s_vs_dense": paged["tok_s_vs_dense"],
            "paged_reserved_cache_bytes": paged["reserved_cache_bytes"],
            "dense_reserved_cache_bytes": dense["reserved_cache_bytes"],
            "paged_max_ctx_at_dense_hbm": paged["max_ctx_at_dense_hbm"],
            "dense_max_ctx": dense["max_ctx_at_dense_hbm"],
        })
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def main() -> None:
    out = rows()
    mem = paged_rows()
    fast, legacy = out
    dense, paged = mem
    print("name,us_per_call,derived")
    for line in csv_rows(out, mem):
        print(line)
    write_bench_json(out, mem)
    print(f"# fast: {fast['tok']} tok in {fast['dt']:.2f}s "
          f"({fast['tok_s']:.1f} tok/s), {fast['prefill_compiles']} prefill "
          f"compiles for {fast['distinct_prompt_lens']} distinct lengths, "
          f"f={fast['f']:.2f}, balance {fast['mean_admitted_per_cycle']:.2f} "
          f"admits / {fast['mean_decoded_per_cycle']:.1f} decodes per cycle")
    print(f"# legacy: {legacy['tok']} tok in {legacy['dt']:.2f}s "
          f"({legacy['tok_s']:.1f} tok/s), {legacy['prefill_compiles']} "
          f"prefill compiles")
    print(f"# paged ({paged['arch']}): {paged['tok_s']:.1f} tok/s "
          f"({paged['tok_s_vs_dense']:.2f}× dense), reserved cache "
          f"{paged['reserved_cache_bytes'] / 1024:.0f} KiB vs dense "
          f"{dense['reserved_cache_bytes'] / 1024:.0f} KiB, max single "
          f"context at dense HBM {paged['max_ctx_at_dense_hbm']} vs "
          f"{dense['max_ctx_at_dense_hbm']} tokens")
    assert fast["all_done"] and legacy["all_done"]
    assert dense["all_done"] and paged["all_done"]
    assert paged["reserved_cache_bytes"] < dense["reserved_cache_bytes"], (
        "paged pool must reserve less HBM than dense rows")


if __name__ == "__main__":
    main()
