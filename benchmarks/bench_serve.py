"""Serving fast-path benchmark: fused quantum decode + bucketed batched
prefill + cache donation vs. the reference per-token engine, plus the paged
KV cache vs. dense per-slot rows.

    PYTHONPATH=src python -m benchmarks.bench_serve

Measures, on the SAME workload (mixed prompt lengths so the legacy path
recompiles per length):
  * tokens/sec end-to-end (compiles included — recompile overhead is the
    point) for fast and legacy engines, and their ratio;
  * prefill compile count (jit cache probe): fast = one per length bucket,
    legacy = one per distinct prompt length;
  * per-cycle scheduler balance: mean admitted prompts vs. decoded tokens
    per engine cycle and the final HBB `f` ratio;
  * memory: reserved KV-cache bytes (paged pool vs dense rows, sized for
    the same workload) and the max context a single request could grow to
    inside the dense engine's HBM budget.

The paged-vs-dense comparison runs on a full-attention arch (mistral-nemo)
— sliding-window archs keep their O(window) rings and would not exercise
the pool.

PR 3 adds the paged-*kernel* comparison (BENCH_2.json): the same paged
workload through the in-kernel page-table walk (`paged_kernel=True` — on
CPU smoke this is the XLA-fused blockwise reference of the kernel
contract, attending only the live page prefix; on TPU the Pallas kernel)
vs. the PR 2 jnp gathered-view path, on an engine provisioned for long
contexts (`KERNEL_MAX_LEN`), where the gather path pays O(max_len) per
token and the kernel path pays O(context). Plus a long-context row — a
request whose context cannot fit the dense engine's 64-token rows at all.

PR 4 adds the multi-tier comparison (BENCH_3.json): a heterogeneous
MultiEngine pool — a short-context dense tier (many small slots) plus a
long-context paged tier (few large slots; long slots are HBM-expensive) —
serving a mixed short+long workload vs. the best single tier that can
serve the whole workload alone (the long tier; the short tier raises
PromptTooLongError on the long prompts). The pool wins structurally: the
long tier alone must push the short flood through its 2 slots in quanta
whose live-page width follows the resident long contexts, while the pool
keeps shorts on the cheap tier and routes by measured per-tier tok/s
(proportional_split). Token streams stay equivalent to a single engine at
temperature=0.

PR 5 adds the speculative-decode comparison (BENCH_4.json): a big/little
pair — an 8-layer softened target and its first layer as the draft
(`models/draft.py`) — vs. the SAME target serving alone, at k ∈ {2,4,8}
greedy plus acceptance-by-temperature at k=4. The win is structural: k
cheap draft steps plus ONE batched (k+1)-position verify replace up to
k+1 serial target steps, so it shows even on the serializing CPU smoke
box; greedy streams are asserted token-identical to target-only.

PR 6 adds the degraded-mode comparison (BENCH_5.json): the same
dense+paged pool twice, healthy vs. losing its paged tier to injected
step failures mid-run (`serve/faults.py`, DESIGN.md §8). The degraded
run must still finish every request with byte-identical greedy streams
and zero leaked pages; the artifact records the degraded/healthy
throughput ratio, retry/reclaim counts, and the quarantine→healthy
recovery cycle count.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

MAX_SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 8
# the kernel-vs-gather rows run on a long-context-provisioned engine: the
# table is 1024/8 = 128 pages wide while the workload's contexts stay small
KERNEL_MAX_LEN = 1024
LONG_PROMPT = 400
LONG_MAX_NEW = 40
# multi-tier pool shape (BENCH_3): many small short-context slots + few
# HBM-expensive long-context slots
MT_SHORT_REQS = 20
MT_LONG_SLOTS = 2


def _workload(cfg, n_requests: int, max_new: int, seed: int = 0,
              lens: list[int] | None = None):
    rng = np.random.default_rng(seed)
    if lens is None:
        # many distinct lengths across two power-of-2 buckets (≤16, ≤32)
        lens = [int(x) for x in rng.integers(4, 31, n_requests)]
    return [(i, rng.integers(0, cfg.vocab, n).tolist()) for i, n in
            enumerate(lens)]


def _workload_pool_pages(workload, max_new: int, decode_quantum: int,
                         max_slots: int = MAX_SLOTS, max_len: int = MAX_LEN,
                         page_size: int = PAGE_SIZE) -> int:
    """Pool sized to the workload's worst case (+ the reserved trash page)
    instead of max_slots × max_len — the memory the paged engine banks."""
    from repro.serve.engine import worst_case_pages

    max_prompt = max(len(p) for _, p in workload)
    return 1 + max_slots * worst_case_pages(max_prompt, max_new,
                                            decode_quantum, max_len,
                                            page_size)


def serve_once(mode: str, *, arch: str = "h2o-danube-1.8b",
               n_requests: int = 12, max_new: int = 16,
               decode_quantum: int = 8, seed: int = 0,
               warmup: bool = False, reps: int = 1,
               max_slots: int = MAX_SLOTS, max_len: int = MAX_LEN,
               page_size: int = PAGE_SIZE, paged_kernel=True,
               lens: list[int] | None = None) -> dict:
    """mode: "fast" | "legacy" | "paged". `warmup` pre-runs a small workload
    so the timed pass measures steady state (used for the paged-vs-dense
    memory comparison, where compile counts are identical by construction
    and the interesting number is the per-token cost of page indirection);
    `reps` re-runs the timed workload and keeps the fastest pass (host
    scheduling noise dwarfs the per-token delta on CPU smoke). `lens`
    overrides the request lengths (long-context row); `paged_kernel`
    selects the in-kernel table walk vs. the jnp gather escape hatch."""
    from repro.configs import get_config, smoke_config
    from repro.serve.engine import Request, make_engine
    from repro.sharding.axes import single_device_ctx

    cfg = smoke_config(get_config(arch))
    ctx = single_device_ctx()
    work = _workload(cfg, n_requests, max_new, seed, lens=lens)
    warm_work = _workload(cfg, 4, max_new, seed + 1) if warmup else []
    kw = {}
    if mode == "paged":
        # size for the timed workload AND the (slightly longer) warmup pass;
        # the allocator insists one full max_len context must always fit
        pages = _workload_pool_pages(work + warm_work, max_new + 1,
                                     decode_quantum, max_slots, max_len,
                                     page_size)
        kw = dict(paged=True, page_size=page_size, paged_kernel=paged_kernel,
                  num_pages=max(pages, 1 + max_len // page_size))
    eng = make_engine(cfg, ctx, max_slots=max_slots, max_len=max_len,
                      fast=mode != "legacy", decode_quantum=decode_quantum,
                      **kw)
    if warmup:
        eng.run([Request(rid=-1 - i, prompt=p, max_new=max_new + 1)
                 for i, p in warm_work])
    dt = float("inf")
    for rep in range(max(1, reps)):
        reqs = [Request(rid=1000 * rep + i, prompt=p, max_new=max_new)
                for i, p in work]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = min(dt, time.perf_counter() - t0)
    tok = sum(len(r.out) for r in reqs)
    cycles = eng.cycle_log or [{"admitted": 0, "decoded": 0, "f": 0.0}]
    return {
        "mode": mode,
        "arch": arch,
        "tok": tok,
        "dt": dt,
        "tok_s": tok / dt,
        "prefill_compiles": eng.prefill_compiles(),
        "distinct_prompt_lens": len({len(r.prompt) for r in reqs}),
        "f": eng.tracker.f(),
        "reserved_cache_bytes": eng.reserved_cache_bytes(),
        "mean_admitted_per_cycle": float(np.mean([c["admitted"]
                                                  for c in cycles])),
        "mean_decoded_per_cycle": float(np.mean([c["decoded"]
                                                 for c in cycles])),
        "cycles": len(cycles),
        "all_done": all(r.done for r in reqs),
    }


def paged_rows(**kw) -> list[dict]:
    """Dense-fast vs paged on a full-attention arch, with memory columns."""
    from repro.configs import get_config, smoke_config
    from repro.serve.kv_cache import page_bytes

    kw.setdefault("arch", "mistral-nemo-12b")
    kw.setdefault("warmup", True)
    kw.setdefault("reps", 3)
    dense = serve_once("fast", **kw)
    paged = serve_once("paged", **kw)
    paged["tok_s_vs_dense"] = paged["tok_s"] / max(dense["tok_s"], 1e-9)
    cfg = smoke_config(get_config(kw["arch"]))
    # longest context one request could occupy inside the DENSE engine's
    # cache budget, were it granted every page (page-table width permitting)
    per_page = max(1, page_bytes(cfg, PAGE_SIZE))
    paged["max_ctx_at_dense_hbm"] = (
        (dense["reserved_cache_bytes"] // per_page - 1) * PAGE_SIZE)
    dense["max_ctx_at_dense_hbm"] = MAX_LEN      # one dense row, fixed
    return [dense, paged]


def kernel_rows(**kw) -> list[dict]:
    """In-kernel page-table walk vs. the jnp gathered view — both paged, on
    an engine provisioned for long contexts (table width
    KERNEL_MAX_LEN/PAGE_SIZE pages) serving the short-prompt smoke
    workload. The gather path materializes and attends the full table
    width for every token; the kernel path walks only the live page
    prefix, so its per-token cost follows the context, not the
    provisioning."""
    kw.setdefault("arch", "mistral-nemo-12b")
    kw.setdefault("max_len", KERNEL_MAX_LEN)
    kw.setdefault("warmup", True)
    kw.setdefault("reps", 3)
    gather = serve_once("paged", paged_kernel=False, **kw)
    kern = serve_once("paged", **kw)
    kern["tok_s_vs_gather"] = kern["tok_s"] / max(gather["tok_s"], 1e-9)
    gather["tok_s_vs_gather"] = 1.0
    return [kern, gather]


def long_ctx_row(**kw) -> dict:
    """One request whose context (LONG_PROMPT + LONG_MAX_NEW tokens) cannot
    exist under the dense engine's MAX_LEN-token rows at any slot count —
    the PR 2 capacity win, now decoded through the kernel path. Reports the
    pool actually reserved vs. what dense rows at the same provisioned
    max_len would cost."""
    from repro.configs import get_config, smoke_config
    from repro.serve.kv_cache import cache_bytes

    kw.setdefault("arch", "mistral-nemo-12b")
    # rep 1 absorbs the 512-bucket prefill compile; best-of keeps the warm rep
    kw.setdefault("reps", 2)
    row = serve_once("paged", max_len=KERNEL_MAX_LEN,
                     lens=[LONG_PROMPT, 9, 17], max_new=LONG_MAX_NEW, **kw)
    cfg = smoke_config(get_config(kw["arch"]))
    row["ctx"] = LONG_PROMPT + LONG_MAX_NEW
    row["dense_max_ctx"] = MAX_LEN
    row["dense_equiv_cache_bytes"] = cache_bytes(cfg, MAX_SLOTS,
                                                 KERNEL_MAX_LEN, 1)
    return row


def _mt_workload(cfg, seed: int = 0):
    """Mixed traffic: a flood of short prompts plus two long prompts that
    only the long-context tier can hold."""
    rng = np.random.default_rng(seed)
    lens = [int(x) for x in rng.integers(4, 31, MT_SHORT_REQS)]
    lens += [LONG_PROMPT, LONG_PROMPT - 27]
    prng = np.random.default_rng(seed + 1)
    return [(i, prng.integers(0, cfg.vocab, n).tolist()) for i, n in
            enumerate(lens)]


def multi_tier_rows(*, arch: str = "mistral-nemo-12b", max_new: int = 16,
                    decode_quantum: int = 8, reps: int = 3,
                    seed: int = 0) -> list[dict]:
    """Heterogeneous tier pool vs. the best single tier (BENCH_3).

    Tiers: `short` — dense fast engine, MAX_LEN-token slots, MAX_SLOTS of
    them; `long` — paged-kernel engine provisioned for KERNEL_MAX_LEN
    contexts with MT_LONG_SLOTS slots (a long slot's page budget is ~16×
    a whole short slot, so few of them is the honest provisioning). The
    short tier cannot serve the long prompts at all, so the best — only —
    single-tier baseline is the long tier serving everything. Interleaved
    best-of-`reps` timing so both rows see the same host-noise regime;
    outputs are checked token-identical per request (greedy streams must
    not depend on the serving tier)."""
    from repro.configs import get_config, smoke_config
    from repro.serve.engine import (Request, make_engine, worst_case_pages)
    from repro.serve.multi_engine import make_multi_engine
    from repro.sharding.axes import single_device_ctx

    cfg = smoke_config(get_config(arch))
    ctx = single_device_ctx()
    work = _mt_workload(cfg, seed)

    def make_reqs(rep: int) -> list:
        return [Request(rid=1000 * rep + i, prompt=p,
                        max_new=max_new if len(p) < MAX_LEN
                        else LONG_MAX_NEW)
                for i, p in work]

    pages = max(1 + MT_LONG_SLOTS * worst_case_pages(
        LONG_PROMPT, LONG_MAX_NEW + 1, decode_quantum, KERNEL_MAX_LEN,
        PAGE_SIZE), 1 + KERNEL_MAX_LEN // PAGE_SIZE)
    long_kw = dict(paged=True, page_size=PAGE_SIZE, num_pages=pages,
                   max_len=KERNEL_MAX_LEN, max_slots=MT_LONG_SLOTS)
    single_long = make_engine(cfg, ctx, decode_quantum=decode_quantum,
                              **long_kw)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "short", "max_len": MAX_LEN, "max_slots": MAX_SLOTS},
        {"name": "long", **long_kw},
    ], decode_quantum=decode_quantum, seed=0)
    runners = {"single_long": single_long.run, "multi_tier": meng.run}
    for run in runners.values():                   # absorb compiles
        run(make_reqs(99))
    best = {k: float("inf") for k in runners}
    tok, outs, done = {}, {}, {}
    routed = {}
    for rep in range(max(1, reps)):
        for name, run in runners.items():
            if name == "multi_tier":       # per-rep routing counts, not the
                r0 = {t.name: t.routed for t in meng.tiers}  # running total
            reqs = make_reqs(rep)
            t0 = time.perf_counter()
            run(reqs)
            dt = time.perf_counter() - t0
            if name == "multi_tier":
                routed = {t.name: t.routed - r0[t.name] for t in meng.tiers}
            best[name] = min(best[name], dt)
            tok[name] = sum(len(r.out) for r in reqs)
            outs[name] = [r.out for r in reqs]
            done[name] = done.get(name, True) and all(r.done for r in reqs)
    equiv = outs["multi_tier"] == outs["single_long"]
    stats = meng.stats()
    multi = {
        "mode": "multi_tier",
        "arch": arch,
        "tok": tok["multi_tier"],
        "dt": best["multi_tier"],
        "tok_s": tok["multi_tier"] / best["multi_tier"],
        "tiers": {n: {"routed": routed[n], "tok_s": s["tok_s"],
                      "unit_cost": s["unit_cost"]}
                  for n, s in stats["tiers"].items()},
        "token_equiv": bool(equiv),
        "all_done": bool(done["multi_tier"]),
        "reserved_cache_bytes": sum(t.engine.reserved_cache_bytes()
                                    for t in meng.tiers),
    }
    single = {
        "mode": "single_long",
        "arch": arch,
        "tok": tok["single_long"],
        "dt": best["single_long"],
        "tok_s": tok["single_long"] / best["single_long"],
        "all_done": bool(done["single_long"]),
        "reserved_cache_bytes": single_long.reserved_cache_bytes(),
    }
    multi["tok_s_vs_best_single"] = multi["tok_s"] / max(single["tok_s"],
                                                         1e-9)
    return [multi, single]


def multi_csv_rows(mt: list[dict]) -> list[str]:
    """Harness-contract rows for the multi-tier pool (BENCH_3)."""
    lines = []
    for r in mt:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{r['mode']}/tok_s,{us:.0f},{r['tok_s']:.1f}")
    lines.append(f"serve/multi_tier_vs_best_single,0,"
                 f"{mt[0]['tok_s_vs_best_single']:.2f}")
    lines.append(f"serve/multi_tier/token_equiv,0,"
                 f"{int(mt[0]['token_equiv'])}")
    return lines


def write_bench3_json(mt: list[dict],
                      path: str | Path = "BENCH_3.json") -> None:
    """PR 4 perf artifact: heterogeneous tier pool vs. best single tier."""
    multi, single = mt
    doc = {
        "bench": "multi_tier_serving",
        "arch": multi["arch"] + " (smoke)",
        "tiers": multi["tiers"],
        "workload": {"short_requests": MT_SHORT_REQS, "long_requests": 2,
                     "long_prompt": LONG_PROMPT,
                     "long_max_new": LONG_MAX_NEW},
        "multi_tok_s": multi["tok_s"],
        "best_single_tier": "long",
        "best_single_tok_s": single["tok_s"],
        "multi_vs_best_single": multi["tok_s_vs_best_single"],
        "multi_reserved_cache_bytes": multi["reserved_cache_bytes"],
        "single_reserved_cache_bytes": single["reserved_cache_bytes"],
        "token_equiv": multi["token_equiv"],
        "all_done": bool(multi["all_done"] and single["all_done"]),
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


# ------------------------------------------------- speculative decode (PR 5)
SPEC_KS = (2, 4, 8)
SPEC_TEMPS = (0.0, 0.5, 1.0)
SPEC_TARGET_LAYERS = 8
SPEC_ALPHA = 0.2


def spec_decode_rows(*, arch: str = "mistral-nemo-12b", max_new: int = 40,
                     decode_quantum: int = 4, reps: int = 3,
                     seed: int = 0) -> dict:
    """Speculative big/little decode vs. target-only (BENCH_4).

    The pair is built the honest way for a smoke box (DESIGN.md §7): the
    target is an `SPEC_TARGET_LAYERS`-deep GQA model whose deep-layer
    residual contributions are softened (`soften_deep_layers`,
    ×SPEC_ALPHA on layers ≥ 1), the draft is its first layer
    (`draft_from_target` — shared embeddings, so vocab-aligned by
    construction). The softened target IS the model both rows serve, so
    the comparison is apples-to-apples: the speedup is structural (k
    draft steps at ~1/8 cost + one batched K-position verify replace up
    to k+1 serial target steps), not a model downgrade, and the
    greedy streams must be token-identical. Greedy rows at k ∈ SPEC_KS;
    acceptance-by-temperature at k=4 shows the rate the router's effective
    tok/s scales by. One engine per row, reused across best-of-`reps`
    timed passes after a compile-absorbing warmup run."""
    import jax

    from repro.configs import get_config, smoke_config
    from repro.models.draft import draft_from_target, soften_deep_layers
    from repro.models.model import model_defs
    from repro.serve.engine import Engine, Request
    from repro.sharding import params as prm
    from repro.sharding.axes import single_device_ctx
    import dataclasses

    cfg = dataclasses.replace(smoke_config(get_config(arch)),
                              n_layers=SPEC_TARGET_LAYERS)
    ctx = single_device_ctx()
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    params = soften_deep_layers(cfg, params, 1, SPEC_ALPHA)
    dcfg, dparams = draft_from_target(cfg, params, 1)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, int(n)).tolist()
               for n in (5, 9, 11, 14, 7, 12)]        # one 16-token bucket

    def bench(**kw):
        eng = Engine(cfg, params, ctx, max_slots=4, max_len=MAX_LEN,
                     decode_quantum=decode_quantum, **kw)

        def mk(rep):
            return [Request(rid=1000 * rep + i, prompt=list(p),
                            max_new=max_new) for i, p in enumerate(prompts)]
        eng.run(mk(99))                               # absorb compiles
        a0, p0 = eng.spec_accepted, eng.spec_proposed
        best, outs, tok, done = float("inf"), None, 0, True
        for rep in range(max(1, reps)):
            reqs = mk(rep)
            t0 = time.perf_counter()
            eng.run(reqs)
            best = min(best, time.perf_counter() - t0)
            outs = [r.out for r in reqs]
            tok = sum(len(r.out) for r in reqs)
            done = done and all(r.done for r in reqs)
        prop = eng.spec_proposed - p0
        return {
            "tok": tok, "dt": best, "tok_s": tok / best,
            "acceptance": ((eng.spec_accepted - a0) / prop if prop else 0.0),
            "outs": outs, "all_done": done,
        }

    base = bench()
    rows = []
    for k in SPEC_KS:
        r = bench(draft_cfg=dcfg, draft_params=dparams, spec_k=k)
        r.update(mode=f"spec_k{k}", spec_k=k,
                 speedup=r["tok_s"] / max(base["tok_s"], 1e-9),
                 token_equiv=r.pop("outs") == base["outs"])
        rows.append(r)
    accept_by_t = {}
    for t in SPEC_TEMPS:
        if t == 0.0:
            accept_by_t["0.0"] = rows[SPEC_KS.index(4)]["acceptance"]
            continue
        r = bench(draft_cfg=dcfg, draft_params=dparams, spec_k=4,
                  temperature=t, sample_seed=seed)
        accept_by_t[str(t)] = r["acceptance"]
    base["mode"] = "target_only"
    base.pop("outs")
    return {"arch": arch, "base": base, "rows": rows,
            "acceptance_by_temperature": accept_by_t}


def spec_csv_rows(sp: dict) -> list[str]:
    """Harness-contract rows for speculative decode (BENCH_4)."""
    lines = []
    for r in [sp["base"]] + sp["rows"]:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{r['mode']}/tok_s,{us:.0f},{r['tok_s']:.1f}")
    k4 = next(r for r in sp["rows"] if r["spec_k"] == 4)
    lines.append(f"serve/spec_k4_vs_target_only,0,{k4['speedup']:.2f}")
    lines.append(f"serve/spec_k4/acceptance,0,{k4['acceptance']:.3f}")
    equiv = all(r["token_equiv"] for r in sp["rows"])
    lines.append(f"serve/spec/token_equiv,0,{int(equiv)}")
    return lines


def write_bench4_json(sp: dict, path: str | Path = "BENCH_4.json") -> None:
    """PR 5 perf artifact: speculative decode vs target-only."""
    k4 = next(r for r in sp["rows"] if r["spec_k"] == 4)
    doc = {
        "bench": "speculative_decode",
        "arch": sp["arch"] + f" (smoke, {SPEC_TARGET_LAYERS} layers, deep "
                             f"residuals ×{SPEC_ALPHA})",
        "draft": "first target layer, shared embeddings",
        "target_only_tok_s": sp["base"]["tok_s"],
        "rows": [{k: v for k, v in r.items() if k != "outs"}
                 for r in sp["rows"]],
        "speedup_k4": k4["speedup"],
        "acceptance_by_temperature": sp["acceptance_by_temperature"],
        "token_equiv": all(r["token_equiv"] for r in sp["rows"]),
        "all_done": bool(sp["base"]["all_done"]
                         and all(r["all_done"] for r in sp["rows"])),
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


# ------------------------------------------------- degraded-mode pool (PR 6)
def fault_rows(*, arch: str = "mistral-nemo-12b", max_new: int = 16,
               decode_quantum: int = 4, n_requests: int = 10,
               seed: int = 0) -> dict:
    """Degraded-mode serving (BENCH_5): the same dense+paged pool, once
    healthy and once losing its paged tier to injected step failures
    mid-run (DESIGN.md §8). The degraded run must still complete every
    request with byte-identical greedy streams and zero page leaks —
    recovery costs wall clock, never tokens. Reported: degraded/healthy
    throughput ratio and the cycle count from quarantine to restored
    health."""
    from repro.configs import get_config, smoke_config
    from repro.serve.engine import Request
    from repro.serve.faults import Fault, FaultyEngine
    from repro.serve.multi_engine import HealthPolicy, make_multi_engine
    from repro.sharding.axes import single_device_ctx

    cfg = smoke_config(get_config(arch))
    ctx = single_device_ctx()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, int(n)).tolist()
               for n in rng.integers(4, 31, n_requests)]

    def make_reqs(rep: int) -> list:
        return [Request(rid=1000 * rep + i, prompt=p, max_new=max_new)
                for i, p in enumerate(prompts)]

    def make_pool():
        return make_multi_engine(cfg, ctx, [
            {"name": "dense"},
            {"name": "paged", "paged": True, "page_size": PAGE_SIZE},
        ], max_slots=4, max_len=MAX_LEN, decode_quantum=decode_quantum,
            seed=0, concurrent=False,
            policy=HealthPolicy(quarantine_after=2, quarantine_cycles=2,
                                probation_steps=1, retry_backoff=1))

    healthy = make_pool()
    healthy.run(make_reqs(99))                     # absorb compiles
    h_reqs = make_reqs(0)
    t0 = time.perf_counter()
    healthy.run(h_reqs)
    h_dt = time.perf_counter() - t0

    faulted = make_pool()
    faulted.run(make_reqs(98))                     # same warm state
    sick = faulted.tiers[1]
    sick.engine = FaultyEngine(sick.engine,
                               [Fault(kind="raise", at=(2,), n=2)])
    f_reqs = make_reqs(0)
    t0 = time.perf_counter()
    faulted.run(f_reqs)
    f_dt = time.perf_counter() - t0

    raw = sick.engine.engine                       # unwrap the fault proxy
    leaked = raw.alloc.usable_pages - len(raw.alloc.free)
    quarantined_at = next((h["cycle"] for h in faulted.health_log
                           if h["to"] == "quarantined"), -1)
    recovered_at = next((h["cycle"] for h in faulted.health_log
                         if h["to"] == "healthy"), -1)
    h_tok = sum(len(r.out) for r in h_reqs)
    f_tok = sum(len(r.out) for r in f_reqs)
    return {
        "arch": arch,
        "healthy": {"tok": h_tok, "dt": h_dt, "tok_s": h_tok / h_dt,
                    "all_done": all(r.done for r in h_reqs)},
        "faulted": {"tok": f_tok, "dt": f_dt, "tok_s": f_tok / f_dt,
                    "all_done": all(r.done for r in f_reqs),
                    "retries": faulted.retries,
                    "reclaims": sick.reclaims,
                    "dead_letters": len(faulted.dead_letters),
                    "injected": len(sick.engine.fault_log)},
        "degraded_ratio": (f_tok / f_dt) / max(h_tok / h_dt, 1e-9),
        "token_equiv": [r.out for r in f_reqs] == [r.out for r in h_reqs],
        "leaked_pages": int(leaked),
        "recovery_cycles": (recovered_at - quarantined_at
                            if recovered_at >= 0 and quarantined_at >= 0
                            else -1),
        "health_log": faulted.health_log,
    }


def fault_csv_rows(ft: dict) -> list[str]:
    """Harness-contract rows for degraded-mode serving (BENCH_5)."""
    lines = []
    for mode in ("healthy", "faulted"):
        r = ft[mode]
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{mode}_pool/tok_s,{us:.0f},{r['tok_s']:.1f}")
    lines.append(f"serve/faulted_vs_healthy,0,{ft['degraded_ratio']:.2f}")
    lines.append(f"serve/faulted/token_equiv,0,{int(ft['token_equiv'])}")
    lines.append(f"serve/faulted/leaked_pages,0,{ft['leaked_pages']}")
    lines.append(f"serve/faulted/recovery_cycles,0,{ft['recovery_cycles']}")
    return lines


def write_bench5_json(ft: dict, path: str | Path = "BENCH_5.json") -> None:
    """PR 6 perf artifact: degraded-mode pool vs. its healthy twin."""
    doc = {
        "bench": "fault_tolerant_serving",
        "arch": ft["arch"] + " (smoke)",
        "fault": "paged tier step raises at engine steps 2-3 (injected)",
        "healthy_tok_s": ft["healthy"]["tok_s"],
        "faulted_tok_s": ft["faulted"]["tok_s"],
        "degraded_ratio": ft["degraded_ratio"],
        "retries": ft["faulted"]["retries"],
        "reclaims": ft["faulted"]["reclaims"],
        "dead_letters": ft["faulted"]["dead_letters"],
        "token_equiv": ft["token_equiv"],
        "leaked_pages": ft["leaked_pages"],
        "recovery_cycles": ft["recovery_cycles"],
        "health_transitions": ft["health_log"],
        "all_done": bool(ft["healthy"]["all_done"]
                         and ft["faulted"]["all_done"]),
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def rows(**kw) -> list[dict]:
    fast = serve_once("fast", **kw)
    legacy = serve_once("legacy", **kw)
    fast["speedup_vs_legacy"] = fast["tok_s"] / max(legacy["tok_s"], 1e-9)
    legacy["speedup_vs_legacy"] = 1.0
    return [fast, legacy]


def csv_rows(out: list[dict], mem: list[dict] | None) -> list[str]:
    """Harness-contract ``name,us_per_call,derived`` rows (shared with
    benchmarks/run.py so the two emitters can't drift). `mem` is None when
    the paged comparison is unavailable."""
    lines = []
    for r in out:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/{r['mode']}/tok_s,{us:.0f},{r['tok_s']:.1f}")
        lines.append(f"serve/{r['mode']}/prefill_compiles,{us:.0f},"
                     f"{r['prefill_compiles']}")
    lines.append(f"serve/speedup_fast_over_legacy,0,"
                 f"{out[0]['speedup_vs_legacy']:.2f}")
    for r in mem or []:
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/mem/{r['mode']}/reserved_cache_kb,{us:.0f},"
                     f"{r['reserved_cache_bytes'] / 1024:.1f}")
        lines.append(f"serve/mem/{r['mode']}/max_ctx_at_dense_hbm,{us:.0f},"
                     f"{r['max_ctx_at_dense_hbm']}")
    if mem:
        lines.append(f"serve/mem/paged_tok_s_vs_dense,0,"
                     f"{mem[1]['tok_s_vs_dense']:.2f}")
    return lines


def kernel_csv_rows(kern: list[dict], long_row: dict) -> list[str]:
    """Harness-contract rows for the paged-kernel comparison (BENCH_2)."""
    lines = []
    for name, r in zip(("kernel", "gather"), kern):
        us = r["dt"] / max(r["tok"], 1) * 1e6
        lines.append(f"serve/paged_{name}/tok_s,{us:.0f},{r['tok_s']:.1f}")
    lines.append(f"serve/paged_kernel_vs_gather,0,"
                 f"{kern[0]['tok_s_vs_gather']:.2f}")
    us = long_row["dt"] / max(long_row["tok"], 1) * 1e6
    lines.append(f"serve/long_ctx/ctx,{us:.0f},{long_row['ctx']}")
    lines.append(f"serve/long_ctx/tok_s,{us:.0f},{long_row['tok_s']:.1f}")
    lines.append(f"serve/long_ctx/reserved_cache_kb,{us:.0f},"
                 f"{long_row['reserved_cache_bytes'] / 1024:.1f}")
    return lines


def write_bench_json(out: list[dict], mem: list[dict] | None,
                     path: str | Path = "BENCH_1.json") -> None:
    """The per-PR perf artifact — one writer, shared by main(), run.py, CI."""
    fast, legacy = out
    doc = {
        "bench": "serve_fast_path",
        "arch": "h2o-danube-1.8b (smoke)",
        "serve_tok_s": fast["tok_s"],
        "serve_tok_s_legacy": legacy["tok_s"],
        "speedup_fast_over_legacy": fast["speedup_vs_legacy"],
        "prefill_compiles_fast": fast["prefill_compiles"],
        "prefill_compiles_legacy": legacy["prefill_compiles"],
        "distinct_prompt_lens": fast["distinct_prompt_lens"],
        "f_ratio": fast["f"],
    }
    if mem:
        dense, paged = mem
        doc.update({
            "paged_arch": paged["arch"] + " (smoke)",
            "paged_tok_s": paged["tok_s"],
            "paged_tok_s_vs_dense": paged["tok_s_vs_dense"],
            "paged_reserved_cache_bytes": paged["reserved_cache_bytes"],
            "dense_reserved_cache_bytes": dense["reserved_cache_bytes"],
            "paged_max_ctx_at_dense_hbm": paged["max_ctx_at_dense_hbm"],
            "dense_max_ctx": dense["max_ctx_at_dense_hbm"],
        })
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def write_bench2_json(kern: list[dict], long_row: dict,
                      path: str | Path = "BENCH_2.json") -> None:
    """PR 3 perf artifact: in-kernel page-table decode vs. the gathered
    view, plus the long-context point the dense cache cannot represent."""
    kernel, gather = kern
    doc = {
        "bench": "paged_kernel_decode",
        "arch": kernel["arch"] + " (smoke)",
        "table_pages": KERNEL_MAX_LEN // PAGE_SIZE,
        "provisioned_max_len": KERNEL_MAX_LEN,
        "paged_kernel_tok_s": kernel["tok_s"],
        "paged_gather_tok_s": gather["tok_s"],
        "paged_kernel_vs_gather": kernel["tok_s_vs_gather"],
        "long_ctx": long_row["ctx"],
        "long_ctx_tok_s": long_row["tok_s"],
        "long_ctx_reserved_cache_bytes": long_row["reserved_cache_bytes"],
        "long_ctx_dense_equiv_cache_bytes":
            long_row["dense_equiv_cache_bytes"],
        "dense_max_ctx": long_row["dense_max_ctx"],
        "all_done": bool(kernel["all_done"] and gather["all_done"]
                         and long_row["all_done"]),
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def main() -> None:
    out = rows()
    mem = paged_rows()
    kern = kernel_rows()
    long_row = long_ctx_row()
    mt = multi_tier_rows()
    sp = spec_decode_rows()
    ft = fault_rows()
    fast, legacy = out
    dense, paged = mem
    print("name,us_per_call,derived")
    for line in csv_rows(out, mem):
        print(line)
    for line in kernel_csv_rows(kern, long_row):
        print(line)
    for line in multi_csv_rows(mt):
        print(line)
    for line in spec_csv_rows(sp):
        print(line)
    for line in fault_csv_rows(ft):
        print(line)
    write_bench_json(out, mem)
    write_bench2_json(kern, long_row)
    write_bench3_json(mt)
    write_bench4_json(sp)
    write_bench5_json(ft)
    print(f"# fast: {fast['tok']} tok in {fast['dt']:.2f}s "
          f"({fast['tok_s']:.1f} tok/s), {fast['prefill_compiles']} prefill "
          f"compiles for {fast['distinct_prompt_lens']} distinct lengths, "
          f"f={fast['f']:.2f}, balance {fast['mean_admitted_per_cycle']:.2f} "
          f"admits / {fast['mean_decoded_per_cycle']:.1f} decodes per cycle")
    print(f"# legacy: {legacy['tok']} tok in {legacy['dt']:.2f}s "
          f"({legacy['tok_s']:.1f} tok/s), {legacy['prefill_compiles']} "
          f"prefill compiles")
    print(f"# paged ({paged['arch']}): {paged['tok_s']:.1f} tok/s "
          f"({paged['tok_s_vs_dense']:.2f}× dense), reserved cache "
          f"{paged['reserved_cache_bytes'] / 1024:.0f} KiB vs dense "
          f"{dense['reserved_cache_bytes'] / 1024:.0f} KiB, max single "
          f"context at dense HBM {paged['max_ctx_at_dense_hbm']} vs "
          f"{dense['max_ctx_at_dense_hbm']} tokens")
    print(f"# paged kernel (max_len {KERNEL_MAX_LEN}): "
          f"{kern[0]['tok_s']:.1f} tok/s vs gather {kern[1]['tok_s']:.1f} "
          f"({kern[0]['tok_s_vs_gather']:.2f}×)")
    print(f"# long ctx: {long_row['ctx']} tokens (dense rows top out at "
          f"{long_row['dense_max_ctx']}) at {long_row['tok_s']:.1f} tok/s, "
          f"pool {long_row['reserved_cache_bytes'] / 1024:.0f} KiB vs "
          f"{long_row['dense_equiv_cache_bytes'] / 1024:.0f} KiB dense rows "
          f"at the same provisioning")
    print(f"# multi-tier: {mt[0]['tok_s']:.1f} tok/s vs best single tier "
          f"(long alone) {mt[1]['tok_s']:.1f} "
          f"({mt[0]['tok_s_vs_best_single']:.2f}×), routed "
          f"{ {n: t['routed'] for n, t in mt[0]['tiers'].items()} }, "
          f"token_equiv={mt[0]['token_equiv']}")
    assert fast["all_done"] and legacy["all_done"]
    assert dense["all_done"] and paged["all_done"]
    assert paged["reserved_cache_bytes"] < dense["reserved_cache_bytes"], (
        "paged pool must reserve less HBM than dense rows")
    assert kern[0]["all_done"] and kern[1]["all_done"] \
        and long_row["all_done"]
    assert long_row["ctx"] > long_row["dense_max_ctx"]
    assert long_row["reserved_cache_bytes"] < \
        long_row["dense_equiv_cache_bytes"], (
            "long-context pool must undercut dense rows at the same "
            "provisioned max_len")
    assert mt[0]["all_done"] and mt[1]["all_done"]
    assert mt[0]["token_equiv"], (
        "multi-tier greedy streams must match the single engine")
    assert mt[0]["tok_s_vs_best_single"] > 1.0, (
        "tier pool must beat the best single tier on the mixed workload")
    k4 = next(r for r in sp["rows"] if r["spec_k"] == 4)
    print(f"# spec decode: target-only {sp['base']['tok_s']:.1f} tok/s; "
          + ", ".join(f"k={r['spec_k']}: {r['tok_s']:.1f} "
                      f"({r['speedup']:.2f}×, acc {r['acceptance']:.2f})"
                      for r in sp["rows"])
          + f"; acceptance by temperature {sp['acceptance_by_temperature']}")
    assert all(r["all_done"] for r in sp["rows"]) and sp["base"]["all_done"]
    assert all(r["token_equiv"] for r in sp["rows"]), (
        "greedy speculative streams must match target-only decode")
    assert k4["speedup"] > 1.3, (
        f"spec_k=4 must beat target-only by >1.3× (got {k4['speedup']:.2f})")
    print(f"# degraded mode: faulted pool {ft['faulted']['tok_s']:.1f} tok/s "
          f"vs healthy {ft['healthy']['tok_s']:.1f} "
          f"({ft['degraded_ratio']:.2f}×), {ft['faulted']['retries']} "
          f"retries, {ft['faulted']['reclaims']} reclaimed, recovery in "
          f"{ft['recovery_cycles']} cycles, leaked_pages="
          f"{ft['leaked_pages']}, token_equiv={ft['token_equiv']}")
    assert ft["healthy"]["all_done"] and ft["faulted"]["all_done"]
    assert ft["faulted"]["dead_letters"] == 0
    assert ft["token_equiv"], (
        "degraded-mode greedy streams must match the healthy pool")
    assert ft["leaked_pages"] == 0, (
        f"tier failure leaked {ft['leaked_pages']} pages")


if __name__ == "__main__":
    main()
