"""Docs link checker: every relative link in the repo's markdown resolves.

    python tools/check_docs.py [files...]

With no arguments, checks all tracked *.md at the repo root plus docs/.
External links (http/https/mailto) and pure anchors (#...) are skipped;
`path#anchor` links are checked for the path only. Exits non-zero listing
every broken link, so CI fails when a doc rename orphans a reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:")


def check_file(md: Path, root: Path) -> list[str]:
    errors = []
    for m in LINK.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(SKIP) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    root = Path(__file__).resolve().parent.parent
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = sorted(root.glob("*.md")) + sorted(root.glob("docs/**/*.md"))
    missing = [str(f) for f in files if not f.exists()]
    if missing:
        print("\n".join(f"no such file: {f}" for f in missing))
        return 1
    errors = []
    for f in files:
        errors += check_file(f, root)
    if errors:
        print("\n".join(errors))
        return 1
    print(f"checked {len(files)} markdown files, all relative links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
