"""Multi-engine heterogeneous serving example: the paper's CC/FC pool at
request granularity (DESIGN.md §6, docs/architecture.md).

Two tiers under one MultiEngine — a short-context dense tier (many small
slots) and a long-context paged tier (few HBM-expensive slots) — serve a
mixed workload of short prompts plus long prompts only the second tier can
hold. Requests are routed by the proportional_split law over measured
per-tier tok/s; a stalled or pool-exhausted tier's work reroutes instead
of blocking the queue.

    PYTHONPATH=src python examples/serve_multitier.py
    PYTHONPATH=src python examples/serve_multitier.py --smoke   # CI-sized
    PYTHONPATH=src python examples/serve_multitier.py \
        --arch mistral-nemo-12b --requests 20 --long-requests 2
"""
import argparse
import time

import numpy as np

from repro.configs import get_config, smoke_config
from repro.serve.engine import Request, worst_case_pages
from repro.serve.multi_engine import make_multi_engine
from repro.sharding.axes import single_device_ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b",
                    help="full-attention arch so the paged tier is used")
    ap.add_argument("--requests", type=int, default=12,
                    help="short requests (prompts 4-30 tokens)")
    ap.add_argument("--long-requests", type=int, default=2,
                    help="long requests (prompt 200 tokens) that only the "
                         "long-context tier can hold")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--decode-quantum", type=int, default=8)
    ap.add_argument("--serial", action="store_true",
                    help="step tiers one after another instead of in "
                         "parallel threads")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed workload for CI smoke (fast, asserts "
                         "completion)")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.long_requests, args.max_new = 4, 1, 4

    cfg = smoke_config(get_config(args.arch))
    ctx = single_device_ctx()
    short_len, long_len, page = 64, 512, 8
    long_prompt = 200
    long_slots = 2
    pages = max(1 + long_slots * worst_case_pages(
        long_prompt, args.max_new + 1, args.decode_quantum, long_len, page),
        1 + long_len // page)
    meng = make_multi_engine(cfg, ctx, [
        {"name": "short", "max_len": short_len, "max_slots": 4},
        {"name": "long", "max_len": long_len, "max_slots": long_slots,
         "paged": True, "page_size": page, "num_pages": pages},
    ], decode_quantum=args.decode_quantum, concurrent=not args.serial)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 31))).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    reqs += [Request(rid=100 + i,
                     prompt=rng.integers(0, cfg.vocab, long_prompt).tolist(),
                     max_new=args.max_new)
             for i in range(args.long_requests)]
    t0 = time.perf_counter()
    meng.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests / {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s incl. compile) across "
          f"{len(meng.tiers)} tiers, {meng.cycles} pool cycles")
    for name, t in meng.stats()["tiers"].items():
        print(f"  tier {name:6s}: {t['routed']:3d} requests routed, "
              f"{t['decoded']:4d} tokens decoded, "
              f"{t['tok_s']:.1f} tok/s measured")
    for r in reqs:
        tier = meng.assigned[r.rid]
        print(f"  req {r.rid:3d} prompt[{len(r.prompt):3d}] via {tier:6s} "
              f"→ {r.out[:8]}{'…' if len(r.out) > 8 else ''}")
    if args.smoke:
        assert all(r.done for r in reqs), "smoke: all requests must finish"
        assert all(meng.assigned[r.rid] == "long"
                   for r in reqs if len(r.prompt) >= short_len), \
            "smoke: long prompts must land on the long tier"
        print("smoke OK")


if __name__ == "__main__":
    main()
