"""Batched serving example (deliverable b): continuous batching with
bucketed batched prefill, fused quantum decode, HBB admission control,
per-request streams.

    PYTHONPATH=src python examples/serve_batch.py --arch h2o-danube-1.8b
    PYTHONPATH=src python examples/serve_batch.py --legacy   # per-token path
    PYTHONPATH=src python examples/serve_batch.py \
        --arch mistral-nemo-12b --paged   # shared KV page pool
"""
import argparse
import time

import numpy as np

from repro.configs import get_config, smoke_config
from repro.serve.engine import Request, make_engine
from repro.sharding.axes import single_device_ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--decode-quantum", type=int, default=8)
    ap.add_argument("--legacy", action="store_true",
                    help="reference per-token engine (no buckets/quantum)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (shared page pool + per-slot "
                         "page table; full-attention archs)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed workload for CI smoke (fast, asserts "
                         "completion)")
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_new = 4, 4

    cfg = smoke_config(get_config(args.arch))
    ctx = single_device_ctx()
    eng = make_engine(cfg, ctx, max_slots=4, max_len=96,
                      fast=not args.legacy,
                      decode_quantum=args.decode_quantum,
                      paged=args.paged, page_size=8)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 32))).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    tok = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests / {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s incl. compile); admission f = "
          f"{eng.tracker.f():.2f}; prefill compiles = "
          f"{eng.prefill_compiles()} for "
          f"{len({len(r.prompt) for r in reqs})} distinct prompt lengths")
    if args.paged:
        al = eng.alloc
        print(f"  page pool: {al.usable_pages} usable pages × "
              f"{eng.page_size} tokens, peak in use "
              f"{al.usable_pages - al.min_free}, {al.total_grants} grants, "
              f"reserved cache {eng.reserved_cache_bytes() / 1024:.0f} KiB")
    for r in reqs:
        print(f"  req {r.rid:2d} prompt[{len(r.prompt):2d}] → {r.out}")
    if args.smoke:
        assert all(r.done for r in reqs), "smoke: all requests must finish"
        print("smoke OK")


if __name__ == "__main__":
    main()
