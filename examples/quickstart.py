"""Quickstart: the public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]

Builds a family-preserving smoke reduction of any assigned architecture,
runs one training step, then prefill + two decode steps.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models.model import model_defs, synth_batch
from repro.serve.decode import decode_step
from repro.serve.prefill import prefill
from repro.sharding import params as prm
from repro.sharding.axes import single_device_ctx
from repro.train.optimizer import OptConfig
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="no-op compatibility flag: the quickstart already "
                         "runs the family-preserving smoke reduction")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    ctx = single_device_ctx()
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={prm.n_params(model_defs(cfg)):,}")

    # --- one training step -------------------------------------------------
    state = init_state(cfg, jax.random.PRNGKey(0), ctx)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3), ctx))
    batch = synth_batch(cfg, batch=2, seq=64, key=jax.random.PRNGKey(1))
    state, metrics = step(state, batch)
    print(f"train: loss={float(metrics['loss']):.4f} "
          f"|g|={float(metrics['grad_norm']):.3f}")

    if cfg.enc_dec:
        print("(enc-dec serving demo: see tests/test_serve.py)")
        return

    # --- prefill + decode ---------------------------------------------------
    params = state["params"]
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    logits, cache = prefill(cfg, params, toks, ctx, max_len=32)
    nxt = jnp.argmax(logits, -1)
    print(f"prefill: next token {int(nxt[0])}")
    for t in range(2):
        pos = jnp.full((1,), 12 + t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache, nxt, pos, ctx)
        nxt = jnp.argmax(logits, -1)
        print(f"decode[{t}]: token {int(nxt[0])}")


if __name__ == "__main__":
    main()
