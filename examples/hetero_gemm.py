"""Paper-faithful reproduction: HBB ``parallel_for`` over GEMM row-blocks
with REAL heterogeneous executors (no simulation):

  * accelerator class ("FC"): the jitted Pallas-pattern GEMM on row chunks
  * core class ("CC"):        a deliberately-slower interpreted per-row path

    PYTHONPATH=src python examples/hetero_gemm.py [--n 512]

Prints the Fig. 5-style table (configs × chunk sizes) on real wall time and
verifies the result equals the single-shot matmul bit-for-bit structure.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hbb import Body, Dynamic, Params
from repro.kernels.gemm.ref import gemm_ref


class GemmBody(Body):
    """C[b:e] = A[b:e] @ B on two real device-class executors."""

    def __init__(self, A, B, out):
        self.A, self.B, self.out = A, B, out
        self._fast = jax.jit(lambda a, b: a @ b)
        _ = self._fast(self.A[:1], self.B).block_until_ready()  # warm

    def operatorFPGA(self, b, e):
        blk = self._fast(self.A[b:e], self.B)
        self.out[b:e] = np.asarray(blk)

    def operatorCPU(self, b, e):
        # interpreted row-at-a-time numpy: the "slow programmable core"
        Bnp = self._Bnp if hasattr(self, "_Bnp") else np.asarray(self.B)
        self._Bnp = Bnp
        Anp = np.asarray(self.A[b:e])
        for i in range(e - b):
            self.out[b + i] = Anp[i] @ Bnp


def run(n, ncc, nfc, chunk):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    out = np.zeros((n, n), np.float32)
    body = GemmBody(A, B, out)
    p = Params(num_cpu_tokens=ncc, num_fpga_tokens=nfc, fpga_chunk=chunk,
               f0=8.0)
    t0 = time.perf_counter()
    rep = Dynamic(p).parallel_for(0, n, body)
    dt = time.perf_counter() - t0
    ref = np.asarray(gemm_ref(A, B))
    err = float(np.max(np.abs(out - ref)))
    return dt, rep, err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    args = ap.parse_args()
    n = args.n
    print(f"GEMM {n}×{n}; config → wall time (s), f, max err")
    results = {}
    for (ncc, nfc) in [(2, 0), (0, 1), (2, 1)]:
        for chunk in (32, 64, 128):
            if nfc == 0 and chunk != 32:
                continue
            dt, rep, err = run(n, ncc, nfc, chunk)
            assert err < 1e-3, err
            results[(ncc, nfc, chunk)] = dt
            ik = rep.iters_by_kind(
                {r.resource: ("accelerator" if r.resource.startswith("FC")
                              else "core") for r in rep.records})
            print(f"  CC={ncc} FC={nfc} S_f={chunk:4d}: {dt:6.3f}s  "
                  f"f={rep.f_final:6.1f}  split={ik}")
    t_off = min(v for (c, f, _), v in results.items() if c == 0)
    t_het = min(v for (c, f, _), v in results.items() if c > 0 and f > 0)
    print(f"\noffload-only best {t_off:.3f}s, heterogeneous best "
          f"{t_het:.3f}s → reduction {100 * (1 - t_het / t_off):.1f}% "
          f"(paper §6: 25–50 %)")


if __name__ == "__main__":
    main()
