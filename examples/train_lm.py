"""End-to-end training driver (deliverable b): train a small LM for a few
hundred steps on the synthetic copy-structured stream and watch it learn
(loss drops below the unigram entropy once it exploits the copy pattern).

    PYTHONPATH=src python examples/train_lm.py                 # ~2M params, 200 steps
    PYTHONPATH=src python examples/train_lm.py --hundred-m     # ~100M params (slow on CPU)

Exercises the full substrate: sharding ctx, data pipeline with prefetch,
AdamW (+optional int8 moments), checkpoint/restart (kill it mid-run and
rerun — it resumes), and a mid-run simulated failure with recovery.
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, register
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticLM
from repro.sharding.axes import single_device_ctx
from repro.train.elastic import FailureInjector
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig

MINI = ModelConfig(
    name="lm-mini", family="dense", n_layers=4, d_model=128, n_heads=4,
    n_kv_heads=2, head_dim=32, d_ff=384, vocab=2048, act="swiglu",
    attn_chunk=64)

HUNDRED_M = dataclasses.replace(
    MINI, name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    head_dim=64, d_ff=2304, vocab=32_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--moments", choices=["float32", "int8"],
                    default="float32")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = HUNDRED_M if args.hundred_m else MINI
    ctx = single_device_ctx()
    data = SyntheticLM(cfg.vocab, args.seq, seed=0)
    loader = PrefetchLoader(data.iterator(args.batch), ctx)
    ocfg = OptConfig(lr=3e-3, warmup_steps=args.steps // 10,
                     decay_steps=args.steps, moments_dtype=args.moments)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir)
    inj = FailureInjector({args.steps // 2: RuntimeError("injected")}) \
        if args.inject_failure else None

    losses = []

    def log(step, row):
        losses.append(row["loss"])
        if step % 20 == 0:
            print(f"step {step:4d}  loss {row['loss']:.4f}  "
                  f"{row['tokens'] / row['dt']:.0f} tok/s")

    res = train_loop(cfg, ocfg, lcfg, ctx, iter(loader), on_step=log,
                     failure_injector=inj)
    loader.close()
    uni = np.log(cfg.vocab) * 0.75  # rough unigram entropy of the zipf mix
    print(f"\nfirst-5 loss {np.mean(losses[:5]):.3f} → "
          f"last-5 {np.mean(losses[-5:]):.3f} "
          f"(unigram ≈ {uni:.2f}); restarts={res.restarts} "
          f"resumed_from={res.resumed_from}")


if __name__ == "__main__":
    main()
