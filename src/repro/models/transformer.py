"""Unified decoder stack for all LM-family architectures.

The per-layer structure (mixer kind, window, dense/MoE ffn) is derived from
the config into a list of :class:`BlockCfg`, then automatically compressed
into repeating :class:`Segment`s (gemma2 → (local, global)×13, jamba →
8-slot pattern ×4, deepseek → dense ×1 + moe ×59 …). Each segment is
executed with ``lax.scan`` over stacked parameters + full activation remat,
which keeps compile time and activation memory bounded for the 60-layer
236 B-param dry-run cells.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (chunked_ce_loss, embed, embed_defs, mlp,
                                 mlp_defs, rmsnorm, rmsnorm_def, unembed_defs)
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx

F32 = jnp.float32


@dataclass(frozen=True)
class BlockCfg:
    mixer: str          # "attn" | "mamba"
    window: int         # 0 = full attention
    ffn: str            # "dense" | "moe" | "none"
    d_ff: int


@dataclass(frozen=True)
class Segment:
    pattern: tuple[BlockCfg, ...]
    repeat: int


def block_cfg_for_layer(cfg: ModelConfig, i: int) -> BlockCfg:
    mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
    window = cfg.window_for_layer(i) if mixer == "attn" else 0
    if cfg.d_ff == 0 and cfg.moe is None:
        ffn, d_ff = "none", 0
    elif cfg.is_moe_layer(i):
        ffn, d_ff = "moe", cfg.moe.d_expert
    elif cfg.moe is not None and i < cfg.moe.first_dense:
        ffn, d_ff = "dense", cfg.moe.dense_d_ff or cfg.d_ff
    else:
        ffn, d_ff = "dense", cfg.d_ff
    return BlockCfg(mixer, window, ffn, d_ff)


def layer_schedule(cfg: ModelConfig, n_layers: int | None = None,
                   blocks=None) -> tuple[Segment, ...]:
    """Compress the per-layer block list into maximal repeating segments."""
    n = n_layers if n_layers is not None else cfg.n_layers
    if blocks is None:
        blocks = [block_cfg_for_layer(cfg, i) for i in range(n)]
    segs: list[Segment] = []
    i = 0
    while i < len(blocks):
        best_plen, best_reps = 1, 1
        for plen in range(1, min(16, len(blocks) - i) + 1):
            pat = blocks[i:i + plen]
            reps = 1
            while blocks[i + reps * plen:i + (reps + 1) * plen] == pat:
                reps += 1
            if reps > 1 and reps * plen > best_plen * best_reps:
                best_plen, best_reps = plen, reps
        segs.append(Segment(tuple(blocks[i:i + best_plen]), best_reps))
        i += best_plen * best_reps
    assert sum(s.repeat * len(s.pattern) for s in segs) == len(blocks)
    return tuple(segs)


# ------------------------------------------------------------------ blocks
def block_defs(cfg: ModelConfig, bc: BlockCfg):
    d = {"norm1": rmsnorm_def(cfg.d_model)}
    if bc.mixer == "attn":
        d["attn"] = attn_mod.attn_defs(cfg)
    else:
        d["mamba"] = mamba_mod.mamba_defs(cfg)
    if cfg.use_post_norm:
        d["post1"] = rmsnorm_def(cfg.d_model)
    if bc.ffn != "none":
        d["norm2"] = rmsnorm_def(cfg.d_model)
        if bc.ffn == "moe":
            d["moe"] = moe_mod.moe_defs(cfg)
        else:
            d["mlp"] = mlp_defs(dataclasses.replace(cfg), bc.d_ff)
        if cfg.use_post_norm:
            d["post2"] = rmsnorm_def(cfg.d_model)
    return d


def block_apply(cfg: ModelConfig, bc: BlockCfg, p, h, ctx: ShardCtx,
                positions, causal: bool = True):
    """h (B,S,D) seq-sharded → (h', moe stats (2,E) or None)."""
    x = rmsnorm(h, p["norm1"], cfg.norm_eps)
    # explicit SP boundary on bf16 (keeps GSPMD from hoisting gathers into
    # the f32 norm internals); each mixer picks its own internal layout
    x = ctx.constrain(x, ("batch", "seq", None))
    if bc.mixer == "attn":
        y = attn_mod.attention(cfg, p["attn"], x, ctx, window=bc.window,
                               positions=positions, causal=causal)
    else:
        y = mamba_mod.mamba_mixer(cfg, p["mamba"], x, ctx)
    if cfg.use_post_norm:
        y = rmsnorm(y, p["post1"], cfg.norm_eps)
    h = h + y
    stats = None
    if bc.ffn != "none":
        x = rmsnorm(h, p["norm2"], cfg.norm_eps)
        if bc.ffn == "moe":
            y, stats = moe_mod.moe_block(cfg, p["moe"], x, ctx)
        else:
            y = mlp(cfg, p["mlp"], x, ctx)
        if cfg.use_post_norm:
            y = rmsnorm(y, p["post2"], cfg.norm_eps)
        h = h + y
    return h, stats


# ------------------------------------------------------------------- stack
def stack_defs(cfg: ModelConfig, segments):
    seg_defs = []
    for seg in segments:
        slot = {f"s{j}": block_defs(cfg, bc) for j, bc in enumerate(seg.pattern)}
        seg_defs.append(prm.stack(slot, seg.repeat))
    return seg_defs


def apply_stack(cfg: ModelConfig, segments, seg_params, h, ctx: ShardCtx,
                positions, causal: bool = True):
    """Returns (h, summed moe stats (2,E) or None)."""
    total_stats = None

    for seg, sp in zip(segments, seg_params):

        def body(hc, slot_params, seg=seg):
            stats_acc = None
            for j, bc in enumerate(seg.pattern):
                hc, st = block_apply(cfg, bc, slot_params[f"s{j}"], hc, ctx,
                                     positions, causal)
                if st is not None:
                    stats_acc = st if stats_acc is None else stats_acc + st
            if stats_acc is None and cfg.moe is not None:
                stats_acc = jnp.zeros((2, cfg.moe.n_experts), F32)
            return hc, stats_acc

        body = jax.checkpoint(body, prevent_cse=False)

        def scan_body(hc, slot_params):
            return body(hc, slot_params)

        h, ys = jax.lax.scan(scan_body, h, sp)
        if ys is not None and cfg.moe is not None:
            st = jnp.sum(ys, axis=0)
            total_stats = st if total_stats is None else total_stats + st
    return h, total_stats


# ----------------------------------------------------------------- LM model
def lm_defs(cfg: ModelConfig):
    segments = layer_schedule(cfg)
    return {
        "embed": embed_defs(cfg),
        "blocks": stack_defs(cfg, segments),
        "final_norm": rmsnorm_def(cfg.d_model),
        "unembed": unembed_defs(cfg),
    }


def lm_hidden(cfg: ModelConfig, params, tokens, ctx: ShardCtx,
              frontend_embed=None):
    """tokens (B,S) → final hidden states (B,S,D) seq-sharded."""
    segments = layer_schedule(cfg)
    h = embed(cfg, params["embed"], tokens, ctx, frontend_embed)
    positions = jnp.arange(tokens.shape[1])
    h, stats = apply_stack(cfg, segments, params["blocks"], h, ctx, positions)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, stats


def lm_loss(cfg: ModelConfig, params, batch, ctx: ShardCtx):
    """batch: tokens/targets/mask (+frontend_embed). → (loss, metrics)."""
    h, stats = lm_hidden(cfg, params, batch["tokens"], ctx,
                         batch.get("frontend_embed"))
    sum_l, sum_c = chunked_ce_loss(cfg, params["embed"], params["unembed"], h,
                                   batch["targets"], batch["mask"], ctx)
    ce = sum_l / jnp.maximum(sum_c, 1.0)
    metrics = {"ce": ce, "tokens": sum_c}
    loss = ce
    if cfg.moe is not None and stats is not None:
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
        aux = moe_mod.aux_loss_from_stats(cfg, stats / max(n_moe, 1))
        metrics["moe_aux"] = aux
        loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics
