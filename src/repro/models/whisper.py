"""Whisper-style encoder-decoder (audio family).

Per the assignment, the conv/mel frontend is a STUB: the batch provides
post-conv frame embeddings (B, frames, d_model). Positions are fixed
sinusoids (encoder) / learned (decoder). Decoder blocks = causal self-attn +
cross-attn + MLP; cross-KV per layer is computed from the encoder output
inside the scanned block (enc_out is scan-invariant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import (chunked_ce_loss, mlp, mlp_defs, rmsnorm,
                                 rmsnorm_def)
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx
from repro.sharding.params import pd

F32 = jnp.float32


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    """Whisper's fixed sinusoidal positional embedding."""
    scale = jnp.exp(-jnp.log(10000.0) / (channels // 2 - 1)
                    * jnp.arange(channels // 2, dtype=F32))
    t = jnp.arange(length, dtype=F32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


# ------------------------------------------------------------------- defs
def enc_block_defs(cfg: ModelConfig):
    return {
        "norm1": rmsnorm_def(cfg.d_model),
        "attn": attn_mod.gqa_defs(cfg),
        "norm2": rmsnorm_def(cfg.d_model),
        "mlp": mlp_defs(cfg, cfg.d_ff),
    }


def dec_block_defs(cfg: ModelConfig):
    return {
        "norm1": rmsnorm_def(cfg.d_model),
        "self_attn": attn_mod.gqa_defs(cfg),
        "norm_x": rmsnorm_def(cfg.d_model),
        "cross": attn_mod.cross_attn_defs(cfg),
        "norm2": rmsnorm_def(cfg.d_model),
        "mlp": mlp_defs(cfg, cfg.d_ff),
    }


def encdec_defs(cfg: ModelConfig):
    return {
        "embed": {"table": pd((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                              dtype=cfg.pdtype)},
        "dec_pos": pd((cfg.max_decoder_len, cfg.d_model), (None, "embed"),
                      scale=0.01, dtype=cfg.pdtype),
        "enc_blocks": prm.stack(enc_block_defs(cfg), cfg.n_enc_layers),
        "enc_norm": rmsnorm_def(cfg.d_model),
        "dec_blocks": prm.stack(dec_block_defs(cfg), cfg.n_layers),
        "dec_norm": rmsnorm_def(cfg.d_model),
        "unembed": {},  # tied to embed.table
    }


# ------------------------------------------------------------------ encode
def encode(cfg: ModelConfig, params, frames, ctx: ShardCtx):
    """frames (B, S_enc, d_model) stub embeddings → encoder states."""
    h = frames.astype(cfg.pdtype) + sinusoids(
        frames.shape[1], cfg.d_model).astype(cfg.pdtype)[None]
    h = ctx.constrain(h, ("batch", "seq", None))
    positions = jnp.arange(frames.shape[1])

    def body(hc, p):
        x = rmsnorm(hc, p["norm1"], cfg.norm_eps)
        x = ctx.constrain(x, ("batch", "seq", None))
        y = attn_mod.attention(cfg, p["attn"], x, ctx, window=0,
                               positions=positions, causal=False)
        hc = hc + y
        x = rmsnorm(hc, p["norm2"], cfg.norm_eps)
        hc = hc + mlp(cfg, p["mlp"], x, ctx)
        return hc, None

    body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------------ decode
def decode_hidden(cfg: ModelConfig, params, tokens, enc_out, ctx: ShardCtx):
    """tokens (B, Td) → decoder hidden (B, Td, D)."""
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.pdtype)
    h = h + params["dec_pos"][None, :tokens.shape[1]]
    h = ctx.constrain(h, ("batch", "seq", None))
    positions = jnp.arange(tokens.shape[1])
    # gather encoder states once; each decoder layer builds its own KV
    enc_out = ctx.constrain(enc_out, ("batch", None, None))

    def body(hc, p):
        x = rmsnorm(hc, p["norm1"], cfg.norm_eps)
        x = ctx.constrain(x, ("batch", "seq", None))
        hc = hc + attn_mod.attention(cfg, p["self_attn"], x, ctx, window=0,
                                     positions=positions, causal=True)
        # cross attention: the ≤448-token decoder side is replicated over
        # `model` (tiny); encoder KV stays gathered — no in-scan collectives
        x = rmsnorm(hc, p["norm_x"], cfg.norm_eps)
        x = ctx.constrain(x, ("batch", None, None))
        k, v = attn_mod.cross_kv(cfg, p["cross"], enc_out, ctx)
        y = attn_mod.cross_attention(cfg, p["cross"], x, k, v, ctx)
        hc = hc + ctx.constrain(y, ("batch", "seq", None))
        x = rmsnorm(hc, p["norm2"], cfg.norm_eps)
        hc = hc + mlp(cfg, p["mlp"], x, ctx)
        return hc, None

    body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    return rmsnorm(h, params["dec_norm"], cfg.norm_eps)


def encdec_loss(cfg: ModelConfig, params, batch, ctx: ShardCtx):
    """batch: frames (B,Se,D), tokens/targets/mask (B,Td)."""
    enc_out = encode(cfg, params, batch["frames"], ctx)
    h = decode_hidden(cfg, params, batch["tokens"], enc_out, ctx)
    sum_l, sum_c = chunked_ce_loss(cfg, params["embed"], params["unembed"], h,
                                   batch["targets"], batch["mask"], ctx,
                                   chunk=min(512, batch["tokens"].shape[1]))
    ce = sum_l / jnp.maximum(sum_c, 1.0)
    return ce, {"ce": ce, "loss": ce, "tokens": sum_c}
