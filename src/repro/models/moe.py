"""Mixture-of-Experts with expert parallelism over the ``model`` mesh axis.

Design (DESIGN.md §3): after attention, tokens are *replicated* across the
``model`` axis (Megatron-SP gathers the sequence), so each model-shard can
compute **only its local experts** for all of its tokens and the top-k
combine is a plain sum → one ``psum_scatter`` returns to the seq-sharded
residual. No all-to-all. Routing uses the standard sort → fixed per-expert
capacity buffers → batched matmul discipline (capacity-dropped tokens follow
Switch-Transformer semantics).

Expert weights are additionally FSDP-sharded over ``data`` on d_model and
all-gathered just-in-time inside the shard_map body (ZeRO-3).

The whole block is an explicit ``shard_map`` so every collective is chosen
by us, not GSPMD — this is the layer the §Perf iterations tune.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import gate_fn, is_gated, activation
from repro.sharding.axes import ShardCtx
from repro.sharding.params import pd

F32 = jnp.float32


def moe_defs(cfg: ModelConfig):
    m = cfg.moe
    E, F, D = m.n_experts, m.d_expert, cfg.d_model
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    d = {
        "router": pd((D, E), ("embed", None), dtype=jnp.float32),
        "w_up": pd((E, D, F), ("experts", "embed", None), dtype=cfg.pdtype),
        "w_down": pd((E, F, D), ("experts", None, "embed"), scale=out_scale,
                     dtype=cfg.pdtype),
    }
    if is_gated(cfg.act):
        d["w_gate"] = pd((E, D, F), ("experts", "embed", None), dtype=cfg.pdtype)
    if m.n_shared:
        Fs = m.n_shared * m.d_expert
        d["ws_up"] = pd((D, Fs), ("embed", "mlp"), dtype=cfg.pdtype)
        d["ws_down"] = pd((Fs, D), ("mlp", "embed"), scale=out_scale,
                          dtype=cfg.pdtype)
        if is_gated(cfg.act):
            d["ws_gate"] = pd((D, Fs), ("embed", "mlp"), dtype=cfg.pdtype)
    return d


def _gather_except(x, spec: P, keep=("model",)):
    """All-gather every sharded dim except mesh axes in `keep` (ZeRO-3)."""
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in reversed(axes):          # minor axis first → correct order
            if ax not in keep:
                x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
    return x


def moe_block(cfg: ModelConfig, p, x, ctx: ShardCtx):
    """x (B, S, D) seq-sharded → (out seq-sharded, router stats (2, E)).

    stats rows: [mean softmax prob per expert, fraction of slots per expert];
    combine into the aux loss with ``aux_loss_from_stats``.
    """
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    msize = ctx.axis_size("model")
    assert E % msize == 0, (E, msize)
    E_loc = E // msize
    gated = is_gated(cfg.act)
    mesh = ctx.mesh
    bspec = ctx.spec(("batch", "seq", None), x.shape)
    pspecs = {n: ctx.spec(d.axes, d.shape)
              for n, d in _defs_meta(cfg).items()}

    def local(x_loc, params):
        midx = jax.lax.axis_index("model")
        xg = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True)
        b, S, D = xg.shape
        T = b * S
        xf = xg.reshape(T, D)

        router = _gather_except(params["router"], pspecs["router"])
        w_up = _gather_except(params["w_up"], pspecs["w_up"])
        w_down = _gather_except(params["w_down"], pspecs["w_down"])

        logits = jnp.einsum("td,de->te", xf, router.astype(xf.dtype),
                            preferred_element_type=F32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)               # (T,k)
        gates = gates / jnp.sum(gates, -1, keepdims=True)

        # ---- stats for the aux loss (identical across the model axis)
        mean_prob = jnp.mean(probs, axis=0)                  # (E,)
        counts_all = jnp.bincount(eidx.reshape(-1), length=E)
        frac = counts_all.astype(F32) / (T * k)
        stats = jnp.stack([mean_prob, frac])[None]           # (1, 2, E)

        # ---- local dispatch: sort by local expert, capacity crop
        e0 = midx * E_loc
        flat_e = eidx.reshape(-1) - e0                       # (T*k,)
        is_local = (flat_e >= 0) & (flat_e < E_loc)
        key_e = jnp.where(is_local, flat_e, E_loc)           # sentinel last
        order = jnp.argsort(key_e, stable=True)
        sorted_e = key_e[order]
        counts = jnp.bincount(key_e, length=E_loc + 1)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(T * k) - offsets[sorted_e]
        Ce = max(1, math.ceil(T * k * m.capacity_factor / E))
        keep = (sorted_e < E_loc) & (pos < Ce)
        tok = order // k
        rows = jnp.where(keep, sorted_e * Ce + pos, E_loc * Ce)
        buf = jnp.zeros((E_loc * Ce + 1, D), xg.dtype)
        buf = buf.at[rows].set(xf[tok], mode="drop")
        buf = buf[:E_loc * Ce].reshape(E_loc, Ce, D)

        h = jnp.einsum("ecd,edf->ecf", buf, w_up)
        if gated:
            w_gate = _gather_except(params["w_gate"], pspecs["w_gate"])
            h = gate_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * h
        else:
            h = activation(cfg.act)(h)
        eo = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * Ce, D)
        eo = jnp.concatenate([eo, jnp.zeros((1, D), eo.dtype)], axis=0)

        slot = eo[rows] * (gates.reshape(-1)[order] * keep)[:, None].astype(eo.dtype)
        out = jnp.zeros((T, D), eo.dtype).at[tok].add(slot)

        # ---- shared experts fold into the same psum_scatter
        if m.n_shared:
            ws_up = params["ws_up"]          # (D, Fs/msize) local slice
            ws_up = _gather_except(ws_up, pspecs["ws_up"])
            ws_down = _gather_except(params["ws_down"], pspecs["ws_down"])
            hs = jnp.einsum("td,df->tf", xf, ws_up)
            if gated:
                ws_gate = _gather_except(params["ws_gate"], pspecs["ws_gate"])
                hs = gate_fn(cfg.act)(jnp.einsum("td,df->tf", xf, ws_gate)) * hs
            else:
                hs = activation(cfg.act)(hs)
            out = out + jnp.einsum("tf,fd->td", hs, ws_down)

        out = out.reshape(b, S, D)
        out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                   tiled=True)
        return out, stats

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    stats_spec = P(dp if dp else None, None, None)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(bspec, {n: pspecs[n] for n in p}),
                   out_specs=(bspec, stats_spec),
                   check_rep=False)
    out, stats = fn(x, dict(p))
    return out, jnp.mean(stats, axis=0)


def moe_decode(cfg: ModelConfig, p, x, ctx: ShardCtx):
    """Decode-path MoE: x (B, D) batch-sharded, no sequence to scatter over —
    each model-shard computes its local experts for its batch rows, combine
    is a plain psum over ``model``."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    msize = ctx.axis_size("model")
    E_loc = E // msize
    gated = is_gated(cfg.act)
    mesh = ctx.mesh
    xspec = ctx.spec(("batch", None), x.shape)
    pspecs = {n: ctx.spec(d.axes, d.shape)
              for n, d in _defs_meta(cfg).items()}

    def local(xf, params):
        midx = jax.lax.axis_index("model")
        T, D = xf.shape
        router = _gather_except(params["router"], pspecs["router"])
        w_up = _gather_except(params["w_up"], pspecs["w_up"])
        w_down = _gather_except(params["w_down"], pspecs["w_down"])
        logits = jnp.einsum("td,de->te", xf, router.astype(xf.dtype),
                            preferred_element_type=F32)
        gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        gates = gates / jnp.sum(gates, -1, keepdims=True)
        e0 = midx * E_loc
        # decode batches are small: dense per-local-expert masked compute
        out = jnp.zeros((T, D), xf.dtype)
        onehot = jax.nn.one_hot(eidx - e0, E_loc, dtype=F32)      # (T,k,E_loc)
        w_tok = jnp.einsum("tke,tk->te", onehot, gates)           # (T,E_loc)
        for el in range(E_loc):
            h = jnp.einsum("td,df->tf", xf, w_up[el])
            if gated:
                w_gate = _gather_except(params["w_gate"], pspecs["w_gate"])
                h = gate_fn(cfg.act)(jnp.einsum("td,df->tf", xf,
                                                w_gate[el])) * h
            else:
                h = activation(cfg.act)(h)
            o = jnp.einsum("tf,fd->td", h, w_down[el])
            out = out + o * w_tok[:, el:el + 1].astype(o.dtype)
        if m.n_shared:
            ws_up = _gather_except(params["ws_up"], pspecs["ws_up"])
            ws_down = _gather_except(params["ws_down"], pspecs["ws_down"])
            hs = jnp.einsum("td,df->tf", xf, ws_up)
            if gated:
                ws_gate = _gather_except(params["ws_gate"], pspecs["ws_gate"])
                hs = gate_fn(cfg.act)(jnp.einsum("td,df->tf", xf, ws_gate)) * hs
            else:
                hs = activation(cfg.act)(hs)
            out = out + jnp.einsum("tf,fd->td", hs, ws_down)
        return jax.lax.psum(out, "model")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(xspec, {n: pspecs[n] for n in p}),
                   out_specs=xspec, check_rep=False)
    return fn(x, dict(p))


def _defs_meta(cfg):
    return moe_defs(cfg)


def aux_loss_from_stats(cfg: ModelConfig, stats) -> jax.Array:
    """stats (2, E) or summed over layers (n, 2, E)."""
    m = cfg.moe
    if stats.ndim == 3:
        stats = jnp.mean(stats, axis=0)
    mean_prob, frac = stats[0], jax.lax.stop_gradient(stats[1])
    return m.aux_weight * m.n_experts * jnp.sum(mean_prob * frac)
