"""Model facade: one API over the LM stack and the enc-dec stack.

``model_defs(cfg)`` → parameter-definition tree
``loss_fn(cfg, params, batch, ctx)`` → (loss, metrics)
``synth_batch(cfg, batch, seq, key)`` → real random batch (tests/examples)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer, whisper
from repro.sharding.axes import ShardCtx


def model_defs(cfg: ModelConfig):
    if cfg.enc_dec:
        return whisper.encdec_defs(cfg)
    return transformer.lm_defs(cfg)


def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx):
    if cfg.enc_dec:
        return whisper.encdec_loss(cfg, params, batch, ctx)
    return transformer.lm_loss(cfg, params, batch, ctx)


def synth_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array):
    """Random batch with the right structure for `loss_fn` (smoke/tests)."""
    kt, kf = jax.random.split(key)
    if cfg.enc_dec:
        td = min(cfg.max_decoder_len, 32)
        tokens = jax.random.randint(kt, (batch, td + 1), 0, cfg.vocab)
        return {
            "frames": jax.random.normal(kf, (batch, seq, cfg.d_model),
                                        jnp.float32) * 0.1,
            "tokens": tokens[:, :-1],
            "targets": tokens[:, 1:],
            "mask": jnp.ones((batch, td), jnp.float32),
        }
    tokens = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab)
    out = {
        "tokens": tokens[:, :-1],
        "targets": tokens[:, 1:],
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.frontend != "none":
        ft = min(cfg.frontend_tokens, seq // 2)
        out["frontend_embed"] = jax.random.normal(
            kf, (batch, ft, cfg.frontend_dim), jnp.float32) * 0.1
        mask = out["mask"].at[:, :ft].set(0.0)  # no loss on patch positions
        out["mask"] = mask
    return out
