"""Attention: GQA / MLA / sliding-window, with a chunked online-softmax core.

The XLA training/prefill path (``attend_chunked``) scans over the *block
pairs* (q-chunk, kv-chunk) that the mask actually allows — causal masks cost
~T²/2 and sliding windows cost O(T·w) — carrying flash-style (o, m, l)
accumulators. Memory is O(T·d) (no T×T score tensor), so the 32 k-prefill
cells compile and fit. The Pallas flash-attention kernel
(``repro.kernels.flash_attention``) is the TPU-optimised equivalent,
validated against the same reference.

Sharding: q heads over ``model``; kv heads over ``model`` iff divisible
(else replicated — cheap for GQA); sequence gathered at entry, output
reduce-scattered back to the seq-sharded residual (Megatron SP).
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rmsnorm, rmsnorm_def, rope_tables
from repro.sharding.axes import ShardCtx
from repro.sharding.params import pd

F32 = jnp.float32
NEG = -1e30


# ------------------------------------------------------------- block pairs
def block_pairs(Tq: int, Tk: int, qc: int, kc: int, *, causal: bool,
                window: int, q_offset: int = 0) -> np.ndarray:
    """Static (P, 2) int32 array of (q_chunk, kv_chunk) indices that contain
    at least one unmasked (i, j) position."""
    nq, nk = -(-Tq // qc), -(-Tk // kc)
    pairs = []
    for qi in range(nq):
        q0 = qi * qc + q_offset          # global position of first query row
        q1 = min(qi * qc + qc, Tq) - 1 + q_offset
        for kj in range(nk):
            k0 = kj * kc
            k1 = min(kj * kc + kc, Tk) - 1
            if causal and k0 > q1:
                continue
            if window and k1 <= q0 - window:
                continue
            pairs.append((qi, kj))
    assert pairs, "empty attention mask"
    return np.asarray(pairs, dtype=np.int32)


def _mask_block(qs, ks, qc, kc, *, causal, window, q_offset, Tq, Tk, Tqp,
                Tkp, kv_offset=0):
    iq = qs + jnp.arange(qc) + q_offset
    jk = ks + jnp.arange(kc) + kv_offset
    ok = jnp.ones((qc, kc), bool)
    if causal:
        ok &= jk[None, :] <= iq[:, None]
    if window:
        ok &= jk[None, :] > iq[:, None] - window
    if not isinstance(kv_offset, int) or kv_offset != 0:
        ok &= jk[None, :] >= 0          # neighbor-exchange boundary shards
    if Tq != Tqp or Tk != Tkp:  # padding rows/cols
        ok &= (iq[:, None] - q_offset < Tq) & \
              (jk[None, :] - kv_offset < Tk)
    return ok


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _attend_core(q, k, v, scale: float, causal: bool, window: int,
                 softcap: float, q_chunk: int, kv_chunk: int, q_offset: int):
    out, _ = _attend_fwd(q, k, v, scale, causal, window, softcap, q_chunk,
                         kv_chunk, q_offset)
    return out


def attend_chunked(q, k, v, *, scale: float, causal: bool = True,
                   window: int = 0, softcap: float = 0.0, q_chunk: int = 512,
                   kv_chunk: int = 512, q_offset=0):
    """Flash attention, XLA path (O(T·d) memory in fwd AND bwd).

    q (B,Tq,Hkv,G,dh), k (B,Tk,Hkv,dh), v (B,Tk,Hkv,dv) → (B,Tq,Hkv,G,dv).
    G = query-group size (GQA); pass G=1 slices for MHA/MLA.

    Static ``q_offset`` (head-parallel path): custom-VJP flash backward, and
    only the block pairs the mask allows are scanned (causal ≈ T²/2).
    Traced ``q_offset`` (context-parallel path, per-shard offset): plain
    AD-through-scan over the full block rectangle with traced masks — the
    CP shard's q is 1/msize of the sequence, so the scan carry stays small.
    """
    if isinstance(q_offset, (int, np.integer)):
        return _attend_core(q, k, v, scale, causal, window, softcap, q_chunk,
                            kv_chunk, int(q_offset))
    return _attend_scan(q, k, v, scale=scale, causal=causal, window=window,
                        softcap=softcap, q_chunk=q_chunk, kv_chunk=kv_chunk,
                        q_offset=q_offset)


def _attend_scan(q, k, v, *, scale, causal, window, softcap, q_chunk,
                 kv_chunk, q_offset, kv_offset=0):
    """Differentiable-through-scan variant accepting traced q/kv offsets."""
    B, Tq, Hkv, G, dh = q.shape
    Tk, dv = k.shape[1], v.shape[-1]
    qc, kc = min(q_chunk, Tq), min(kv_chunk, Tk)
    qp, kp, vp = _pad_qkv(q, k, v, qc, kc)
    Tqp, Tkp = qp.shape[1], kp.shape[1]
    pairs = jnp.asarray(
        [(i, j) for i in range(Tqp // qc) for j in range(Tkp // kc)],
        jnp.int32)

    o0 = jnp.zeros((B, Tqp, Hkv, G, dv), F32)
    m0 = jnp.full((B, Tqp, Hkv, G), NEG, F32)
    l0 = jnp.zeros((B, Tqp, Hkv, G), F32)

    def block(q_blk, k_blk, v_blk, o_old, m_old, l_old, qs, ks):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(F32) * scale,
                       k_blk.astype(F32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        ok = _mask_block(qs, ks, qc, kc, causal=causal, window=window,
                         q_offset=q_offset, Tq=Tq, Tk=Tk, Tqp=Tqp, Tkp=Tkp,
                         kv_offset=kv_offset)
        s = jnp.where(ok[None, None, None], s, NEG)
        m_blk = jnp.moveaxis(jnp.max(s, axis=-1), -1, 1)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(m_new <= NEG / 2, 0.0, m_new)
        p = jnp.exp(s - jnp.moveaxis(m_safe, 1, -1)[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(m_old <= NEG / 2, NEG, m_old) - m_safe)
        o_new = (o_old * corr[..., None]
                 + jnp.einsum("bhgqk,bkhd->bqhgd", p, v_blk.astype(F32)))
        l_new = l_old * corr + jnp.moveaxis(jnp.sum(p, -1), -1, 1)
        return o_new, m_new, l_new

    block = jax.checkpoint(block, prevent_cse=False)

    def body(carry, pair):
        o, m, l = carry
        qs, ks = pair[0] * qc, pair[1] * kc
        args = [jax.lax.dynamic_slice_in_dim(t, qs, qc, 1)
                for t in (qp,)] + \
               [jax.lax.dynamic_slice_in_dim(t, ks, kc, 1) for t in (kp, vp)]
        o_old = jax.lax.dynamic_slice_in_dim(o, qs, qc, 1)
        m_old = jax.lax.dynamic_slice_in_dim(m, qs, qc, 1)
        l_old = jax.lax.dynamic_slice_in_dim(l, qs, qc, 1)
        o_new, m_new, l_new = block(args[0], args[1], args[2], o_old, m_old,
                                    l_old, qs, ks)
        o = jax.lax.dynamic_update_slice_in_dim(o, o_new, qs, 1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, 1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), pairs)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out[:, :Tq].astype(q.dtype)


def _pad_qkv(q, k, v, qc, kc):
    Tq, Tk = q.shape[1], k.shape[1]
    pq, pk = (-Tq) % qc, (-Tk) % kc
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq)) + ((0, 0),) * (q.ndim - 2))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    return q, k, v


def _attend_fwd(q, k, v, scale, causal, window, softcap, q_chunk, kv_chunk,
                q_offset):
    B, Tq, Hkv, G, dh = q.shape
    Tk, dv = k.shape[1], v.shape[-1]
    qc, kc = min(q_chunk, Tq), min(kv_chunk, Tk)
    qp, kp, vp = _pad_qkv(q, k, v, qc, kc)
    Tqp, Tkp = qp.shape[1], kp.shape[1]
    pairs = jnp.asarray(block_pairs(Tq, Tk, qc, kc, causal=causal,
                                    window=window, q_offset=q_offset))

    o0 = jnp.zeros((B, Tqp, Hkv, G, dv), F32)
    m0 = jnp.full((B, Tqp, Hkv, G), NEG, F32)
    l0 = jnp.zeros((B, Tqp, Hkv, G), F32)

    def body(carry, pair):
        o, m, l = carry
        qs, ks = pair[0] * qc, pair[1] * kc
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qs, qc, 1).astype(F32)
        k_blk = jax.lax.dynamic_slice_in_dim(kp, ks, kc, 1).astype(F32)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, ks, kc, 1).astype(F32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk * scale, k_blk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        ok = _mask_block(qs, ks, qc, kc, causal=causal, window=window,
                         q_offset=q_offset, Tq=Tq, Tk=Tk, Tqp=Tqp, Tkp=Tkp)
        s = jnp.where(ok[None, None, None], s, NEG)
        m_old = jax.lax.dynamic_slice_in_dim(m, qs, qc, 1)
        l_old = jax.lax.dynamic_slice_in_dim(l, qs, qc, 1)
        o_old = jax.lax.dynamic_slice_in_dim(o, qs, qc, 1)
        m_blk = jnp.moveaxis(jnp.max(s, axis=-1), -1, 1)     # (B,qc,h,g)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(m_new <= NEG / 2, 0.0, m_new)
        p = jnp.exp(s - jnp.moveaxis(m_safe, 1, -1)[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        corr = jnp.exp(jnp.where(m_old <= NEG / 2, NEG, m_old) - m_safe)
        o_new = (o_old * corr[..., None]
                 + jnp.einsum("bhgqk,bkhd->bqhgd", p, v_blk))
        l_new = l_old * corr + jnp.moveaxis(jnp.sum(p, -1), -1, 1)
        o = jax.lax.dynamic_update_slice_in_dim(o, o_new, qs, 1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, 1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), pairs)
    lsafe = jnp.maximum(l, 1e-30)
    out = (o / lsafe[..., None])[:, :Tq].astype(q.dtype)
    lse = (jnp.where(m <= NEG / 2, 0.0, m) + jnp.log(lsafe))[:, :Tq]
    return out, (q, k, v, out, lse)


def _attend_bwd(scale, causal, window, softcap, q_chunk, kv_chunk, q_offset,
                res, do):
    """Flash backward: recompute p per block from saved lse; plain scans —
    nothing accumulated across AD, so memory stays O(T·d)."""
    q, k, v, out, lse = res
    B, Tq, Hkv, G, dh = q.shape
    Tk, dv = k.shape[1], v.shape[-1]
    qc, kc = min(q_chunk, Tq), min(kv_chunk, Tk)
    qp, kp, vp = _pad_qkv(q, k, v, qc, kc)
    Tqp, Tkp = qp.shape[1], kp.shape[1]
    dop = jnp.pad(do.astype(F32),
                  ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0), (0, 0)))
    lsep = jnp.pad(lse, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
    # delta_i = rowsum(do ⊙ o)
    delta = jnp.sum(dop[:, :Tq] * out.astype(F32), axis=-1)
    delta = jnp.pad(delta, ((0, 0), (0, Tqp - Tq), (0, 0), (0, 0)))
    pairs = jnp.asarray(block_pairs(Tq, Tk, qc, kc, causal=causal,
                                    window=window, q_offset=q_offset))

    dq0 = jnp.zeros((B, Tqp, Hkv, G, dh), F32)
    dk0 = jnp.zeros((B, Tkp, Hkv, dh), F32)
    dv0 = jnp.zeros((B, Tkp, Hkv, dv), F32)

    def body(carry, pair):
        dq, dk, dv_ = carry
        qs, ks = pair[0] * qc, pair[1] * kc
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qs, qc, 1).astype(F32)
        k_blk = jax.lax.dynamic_slice_in_dim(kp, ks, kc, 1).astype(F32)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, ks, kc, 1).astype(F32)
        do_blk = jax.lax.dynamic_slice_in_dim(dop, qs, qc, 1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lsep, qs, qc, 1)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qs, qc, 1)
        s_pre = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk * scale, k_blk)
        if softcap:
            t = jnp.tanh(s_pre / softcap)
            s = t * softcap
        else:
            s = s_pre
        ok = _mask_block(qs, ks, qc, kc, causal=causal, window=window,
                         q_offset=q_offset, Tq=Tq, Tk=Tk, Tqp=Tqp, Tkp=Tkp)
        s = jnp.where(ok[None, None, None], s, NEG)
        p = jnp.exp(s - jnp.moveaxis(lse_blk, 1, -1)[..., None])
        p = jnp.where(ok[None, None, None], p, 0.0)
        dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, do_blk)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, v_blk)
        ds = p * (dp - jnp.moveaxis(dl_blk, 1, -1)[..., None])
        if softcap:
            ds = ds * (1.0 - t * t)
        dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, k_blk) * scale
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_blk) * scale
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, qs, qc, 1) + dq_blk, qs, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, ks, kc, 1) + dk_blk, ks, 1)
        dv_ = jax.lax.dynamic_update_slice_in_dim(
            dv_, jax.lax.dynamic_slice_in_dim(dv_, ks, kc, 1) + dv_blk, ks, 1)
        return (dq, dk, dv_), None

    (dq, dk, dv_), _ = jax.lax.scan(body, (dq0, dk0, dv0), pairs)
    return (dq[:, :Tq].astype(q.dtype), dk[:, :Tk].astype(k.dtype),
            dv_[:, :Tk].astype(v.dtype))


_attend_core.defvjp(_attend_fwd, _attend_bwd)


# --------------------------------------------------------------- GQA block
def gqa_defs(cfg: ModelConfig):
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    d = {
        "wq": pd((cfg.d_model, cfg.n_heads, cfg.head_dim),
                 ("embed", "heads", "qk"), dtype=cfg.pdtype),
        "wk": pd((cfg.d_model, cfg.n_kv_heads, cfg.head_dim),
                 ("embed", "kv_heads", "qk"), dtype=cfg.pdtype),
        "wv": pd((cfg.d_model, cfg.n_kv_heads, cfg.head_dim),
                 ("embed", "kv_heads", "qk"), dtype=cfg.pdtype),
        "wo": pd((cfg.n_heads, cfg.head_dim, cfg.d_model),
                 ("heads", "qk", "embed"), scale=out_scale, dtype=cfg.pdtype),
    }
    return d


def gqa_project(cfg: ModelConfig, p, x, ctx: ShardCtx, positions):
    """x (B,S,D) → q (B,S,Hkv,G,dh), k,v (B,S,Hkv,dh). Applies rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = ctx.constrain(q, ("batch", None, "heads", None))
    k = ctx.constrain(k, ("batch", None, "kv_heads", None))
    v = ctx.constrain(v, ("batch", None, "kv_heads", None))
    if cfg.use_rope:
        cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    G = cfg.n_heads // cfg.n_kv_heads
    B, S = q.shape[:2]
    q = q.reshape(B, S, cfg.n_kv_heads, G, cfg.head_dim)
    return q, k, v


def gqa_attention(cfg: ModelConfig, p, x, ctx: ShardCtx, *, window: int,
                  positions, causal: bool = True):
    """Full training/prefill GQA attention block (no cache)."""
    q, k, v = gqa_project(cfg, p, x, ctx, positions)
    scale = cfg.head_dim ** -0.5
    out = attend_chunked(q, k, v, scale=scale, causal=causal, window=window,
                         softcap=cfg.attn_softcap, q_chunk=cfg.attn_chunk,
                         kv_chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads, cfg.head_dim)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.constrain(o, ("batch", "seq", None))


# --------------------------------------------------------------- MLA block
def mla_defs(cfg: ModelConfig):
    m = cfg.mla
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wdq": pd((cfg.d_model, m.q_lora), ("embed", "lora"), dtype=cfg.pdtype),
        "q_norm": rmsnorm_def(m.q_lora),
        "wuq": pd((m.q_lora, cfg.n_heads, m.nope_dim + m.rope_dim),
                  ("lora", "heads", "qk"), dtype=cfg.pdtype),
        "wdkv": pd((cfg.d_model, m.kv_lora), ("embed", "lora"), dtype=cfg.pdtype),
        "kv_norm": rmsnorm_def(m.kv_lora),
        "wukv": pd((m.kv_lora, cfg.n_heads, m.nope_dim + m.v_dim),
                   ("lora", "heads", "qk"), dtype=cfg.pdtype),
        "wkr": pd((cfg.d_model, m.rope_dim), ("embed", "qk"), dtype=cfg.pdtype),
        "wo": pd((cfg.n_heads, m.v_dim, cfg.d_model),
                 ("heads", "v", "embed"), scale=out_scale, dtype=cfg.pdtype),
    }


def mla_latents(cfg: ModelConfig, p, x, ctx: ShardCtx, positions):
    """Compressed latents: c_kv (B,S,kv_lora), k_rope (B,S,1,rope) — this pair
    *is* the MLA KV cache."""
    m = cfg.mla
    c_kv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_norm"],
                   cfg.norm_eps)
    k_r = jnp.einsum("bsd,dr->bsr", x, p["wkr"])[:, :, None, :]
    cos, sin = rope_tables(positions, m.rope_dim, cfg.rope_theta)
    k_r = apply_rope(k_r, cos, sin)
    return c_kv, k_r


def mla_queries(cfg: ModelConfig, p, x, ctx: ShardCtx, positions):
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"],
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q = ctx.constrain(q, ("batch", None, "heads", None))
    qn, qr = q[..., :m.nope_dim], q[..., m.nope_dim:]
    cos, sin = rope_tables(positions, m.rope_dim, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    return qn, qr


def mla_attention(cfg: ModelConfig, p, x, ctx: ShardCtx, *, window: int,
                  positions, causal: bool = True):
    """Training/prefill MLA: expand latents to full heads, run chunked core."""
    m = cfg.mla
    B, S, _ = x.shape
    qn, qr = mla_queries(cfg, p, x, ctx, positions)
    c_kv, k_r = mla_latents(cfg, p, x, ctx, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wukv"])
    kv = ctx.constrain(kv, ("batch", None, "heads", None))
    kn, v = kv[..., :m.nope_dim], kv[..., m.nope_dim:]
    k = jnp.concatenate([kn, jnp.broadcast_to(
        k_r, (B, S, cfg.n_heads, m.rope_dim)).astype(kn.dtype)], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)[:, :, :, None, :]  # G=1
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    out = attend_chunked(q, k, v, scale=scale, causal=causal, window=window,
                         softcap=cfg.attn_softcap, q_chunk=cfg.attn_chunk,
                         kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, S, cfg.n_heads, m.v_dim)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.constrain(o, ("batch", "seq", None))


def attn_defs(cfg: ModelConfig):
    return mla_defs(cfg) if cfg.mla else gqa_defs(cfg)


# ------------------------------------------------- context-parallel (CP) GQA
def _gather_fsdp(x, spec, keep=("model",)):
    """All-gather every sharded dim except axes in `keep` (ZeRO-3)."""
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in reversed(axes):
            if ax not in keep:
                x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
    return x


def cp_gqa_attention(cfg: ModelConfig, p, x, ctx: ShardCtx, *, window: int,
                     causal: bool = True, return_kv: bool = False):
    """Context-parallel attention for archs whose head counts don't divide
    the ``model`` axis (gemma2 8H, GQA-8 archs, whisper 20H).

    q stays with its *local sequence rows*; only the (small, GQA) k/v are
    all-gathered over ``model``. Output rows are already seq-sharded, so the
    block has exactly ONE collective per projection set — no gathers inside
    the flash scan, no psum after the out-projection. Causal masking uses
    the traced per-shard q_offset (static block pruning is disabled; the
    rectangle waste shows up in §Roofline and is a §Perf lever)."""
    mesh = ctx.mesh
    xspec = ctx.spec(("batch", "seq", None), x.shape)
    pspecs = {n: ctx.spec(d.axes, d.shape) for n, d in gqa_defs(cfg).items()}
    G = cfg.n_heads // cfg.n_kv_heads

    def local(x_loc, params):
        i = jax.lax.axis_index("model")
        B, S_loc, D = x_loc.shape
        # CP parallelises the *sequence*: weights gather fully (ZeRO-3 over
        # data AND the head shards over model — heads don't divide msize)
        wq = _gather_fsdp(params["wq"], pspecs["wq"], keep=())
        wk = _gather_fsdp(params["wk"], pspecs["wk"], keep=())
        wv = _gather_fsdp(params["wv"], pspecs["wv"], keep=())
        wo = _gather_fsdp(params["wo"], pspecs["wo"], keep=())
        q = jnp.einsum("bsd,dhk->bshk", x_loc, wq)
        k = jnp.einsum("bsd,dhk->bshk", x_loc, wk)
        v = jnp.einsum("bsd,dhk->bshk", x_loc, wv)
        pos_loc = i * S_loc + jnp.arange(S_loc)
        if cfg.use_rope:
            cos, sin = rope_tables(pos_loc, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos[None], sin[None])
            k = apply_rope(k, cos[None], sin[None])
        msize = ctx.axis_size("model")     # static mesh size (jax<0.5 has
        n_nb = -(-window // S_loc) if window else msize  # no lax.axis_size)
        if window and n_nb < msize - 1:
            # window-aware neighbor exchange: shard i only needs kv from
            # [i·S_loc − window, (i+1)·S_loc) → its own rows + n_nb left
            # neighbors via collective_permute — wire and attend-flops drop
            # msize/(n_nb+1)× vs a full all-gather (§Perf iteration 11)
            parts_k, parts_v = [k], [v]
            for d in range(1, n_nb + 1):
                perm = [(s, s + d) for s in range(msize - d)]
                parts_k.insert(0, jax.lax.ppermute(k, "model", perm))
                parts_v.insert(0, jax.lax.ppermute(v, "model", perm))
            kg = jnp.concatenate(parts_k, axis=1)
            vg = jnp.concatenate(parts_v, axis=1)
            kv_off = (i - n_nb) * S_loc
        else:
            kg = jax.lax.all_gather(k, "model", axis=1, tiled=True)
            vg = jax.lax.all_gather(v, "model", axis=1, tiled=True)
            kv_off = 0
        qg = q.reshape(B, S_loc, cfg.n_kv_heads, G, cfg.head_dim)
        out = _attend_scan(qg, kg, vg, scale=cfg.head_dim ** -0.5,
                           causal=causal, window=window,
                           softcap=cfg.attn_softcap,
                           q_chunk=min(cfg.attn_chunk, S_loc),
                           kv_chunk=cfg.attn_chunk,
                           q_offset=i * S_loc, kv_offset=kv_off)
        out = out.reshape(B, S_loc, cfg.n_heads, cfg.head_dim)
        o = jnp.einsum("bshk,hkd->bsd", out, wo)
        if return_kv:
            return o, k, v       # local rows → kv_seq-sharded cache, free
        return o

    bp = xspec[0]
    kvspec = P(bp, "model", None, None)
    out_specs = (xspec, kvspec, kvspec) if return_kv else xspec
    fn = shard_map(local, mesh=mesh, in_specs=(xspec, {n: pspecs[n] for n in p}),
                   out_specs=out_specs, check_rep=False)
    return fn(x, dict(p))


def _cp_eligible(cfg: ModelConfig, ctx: ShardCtx) -> bool:
    msize = ctx.axis_size("model")
    if cfg.mla or msize == 1:
        return False
    return (cfg.n_kv_heads % msize != 0) or (cfg.n_heads % msize != 0)


def attention(cfg: ModelConfig, p, x, ctx: ShardCtx, *, window: int,
              positions, causal: bool = True):
    if cfg.mla:
        return mla_attention(cfg, p, x, ctx, window=window,
                             positions=positions, causal=causal)
    if _cp_eligible(cfg, ctx):
        return cp_gqa_attention(cfg, p, x, ctx, window=window, causal=causal)
    return gqa_attention(cfg, p, x, ctx, window=window, positions=positions,
                         causal=causal)


# ---------------------------------------------------------- cross-attention
def cross_attn_defs(cfg: ModelConfig):
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wq": pd((cfg.d_model, cfg.n_heads, cfg.head_dim),
                 ("embed", "heads", "qk"), dtype=cfg.pdtype),
        "wk": pd((cfg.d_model, cfg.n_kv_heads, cfg.head_dim),
                 ("embed", "kv_heads", "qk"), dtype=cfg.pdtype),
        "wv": pd((cfg.d_model, cfg.n_kv_heads, cfg.head_dim),
                 ("embed", "kv_heads", "qk"), dtype=cfg.pdtype),
        "wo": pd((cfg.n_heads, cfg.head_dim, cfg.d_model),
                 ("heads", "qk", "embed"), scale=out_scale, dtype=cfg.pdtype),
    }


def cross_kv(cfg: ModelConfig, p, enc_out, ctx: ShardCtx):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    k = ctx.constrain(k, ("batch", None, "kv_heads", None))
    v = ctx.constrain(v, ("batch", None, "kv_heads", None))
    return k, v


def cross_attention(cfg: ModelConfig, p, x, k, v, ctx: ShardCtx):
    """x: decoder states (B,Td,D); k/v: precomputed encoder KV (B,Te,H,dh)."""
    B, Td, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = ctx.constrain(q, ("batch", None, "heads", None))
    G = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(B, Td, cfg.n_kv_heads, G, cfg.head_dim)
    out = attend_chunked(q, k, v, scale=cfg.head_dim ** -0.5, causal=False,
                         q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, Td, cfg.n_heads, cfg.head_dim)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.constrain(o, ("batch", "seq", None))


# ----------------------------------------------------------- pure reference
def reference_attention(q, k, v, *, scale, causal, window=0, softcap=0.0,
                        q_offset: int = 0):
    """O(T²)-memory oracle for tests. Same signature/layout as attend_chunked."""
    B, Tq, Hkv, G, dh = q.shape
    Tk = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(F32) * scale, k.astype(F32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    iq = jnp.arange(Tq) + q_offset
    jk = jnp.arange(Tk)
    ok = jnp.ones((Tq, Tk), bool)
    if causal:
        ok &= jk[None, :] <= iq[:, None]
    if window:
        ok &= jk[None, :] > iq[:, None] - window
    s = jnp.where(ok[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(F32))
    return out.astype(q.dtype)
