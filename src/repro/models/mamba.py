"""Mamba mixers: Mamba-1 (selective scan, Jamba) and Mamba-2 (SSD).

XLA paths are *chunked*: sequence is split into chunks; within a chunk the
recurrence is computed with associative-scan / cumsum einsums; a `lax.scan`
carries the SSM state across chunks (linear in T, bounded memory — this is
what makes the 512 k-token cells runnable). The Pallas SSD kernel
(`repro.kernels.ssd`) is the TPU-optimised intra-chunk path.

Sharding: d_inner / SSD heads over ``model`` (replicated when not divisible,
e.g. mamba2-130m's 24 heads on a 16-way axis — noted in EXPERIMENTS.md);
sequence gathered at entry, reduce-scattered at exit (SP), like attention.

Single-token decode steps (`mamba1_step`, `mamba2_step`) carry
(conv_state, ssm_state) — SSMs are O(1)-state decoders, which is exactly why
the long_500k cell is assigned to this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_def
from repro.sharding.axes import ShardCtx
from repro.sharding.params import pd

F32 = jnp.float32


# ------------------------------------------------------------------ common
def causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C), w (K,C), b (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[i] for i in range(K))
    return y + b


def conv_step(conv_state, xt, w, b):
    """conv_state (B,K-1,C), xt (B,C) → (new_state, yt (B,C))."""
    K = w.shape[0]
    full = jnp.concatenate([conv_state, xt[:, None, :]], axis=1)  # (B,K,C)
    yt = jnp.einsum("bkc,kc->bc", full, w) + b
    return full[:, 1:], yt


# ------------------------------------------------------------------ mamba2
def mamba2_defs(cfg: ModelConfig):
    s = cfg.ssm
    D, C = cfg.d_model, cfg.d_inner
    H = C // s.head_dim
    N = s.d_state
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wz": pd((D, C), ("embed", "d_inner"), dtype=cfg.pdtype),
        "wx": pd((D, C), ("embed", "d_inner"), dtype=cfg.pdtype),
        "wB": pd((D, N), ("embed", "ssm_state"), dtype=cfg.pdtype),
        "wC": pd((D, N), ("embed", "ssm_state"), dtype=cfg.pdtype),
        "wdt": pd((D, H), ("embed", "ssm_heads"), dtype=cfg.pdtype),
        "conv_x": pd((s.d_conv, C), ("conv", "d_inner"), scale=0.1,
                     dtype=cfg.pdtype),
        "conv_x_b": pd((C,), ("d_inner",), init="zeros", dtype=cfg.pdtype),
        "conv_B": pd((s.d_conv, N), ("conv", "ssm_state"), scale=0.1,
                     dtype=cfg.pdtype),
        "conv_B_b": pd((N,), ("ssm_state",), init="zeros", dtype=cfg.pdtype),
        "conv_C": pd((s.d_conv, N), ("conv", "ssm_state"), scale=0.1,
                     dtype=cfg.pdtype),
        "conv_C_b": pd((N,), ("ssm_state",), init="zeros", dtype=cfg.pdtype),
        "A_log": pd((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D_skip": pd((H,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": pd((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "gn": rmsnorm_def(C),
        "wo": pd((C, D), ("d_inner", "embed"), scale=out_scale,
                 dtype=cfg.pdtype),
    }


def _mamba2_inputs(cfg, p, x, positions=None):
    """Shared projection+conv for train & decode. x (B,S,D)."""
    z = jnp.einsum("bsd,dc->bsc", x, p["wz"])
    xs = jnp.einsum("bsd,dc->bsc", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(F32)
    return z, xs, Bm, Cm, dt


def ssd_scan(xh, dt_a, Bm, Cm, chunk: int, intra_fn=None):
    """Chunked SSD (state-space duality) core.

    xh (B,S,H,P) [dt already folded in], dt_a (B,S,H) [= dt·A, negative],
    Bm/Cm (B,S,N). Returns y (B,S,H,P) and final state (B,H,P,N).
    `intra_fn` optionally overrides the intra-chunk computation (Pallas).
    """
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S0 = S
    pad = (-S) % Q
    if pad:  # zero x + zero dt·A are exact no-ops for the recurrence
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_a = jnp.pad(dt_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    xc = xh.reshape(B, nc, Q, H, Pd).astype(F32)
    ac = dt_a.reshape(B, nc, Q, H).astype(F32)
    Bc = Bm.reshape(B, nc, Q, N).astype(F32)
    Cc = Cm.reshape(B, nc, Q, N).astype(F32)

    cs = jnp.cumsum(ac, axis=2)                       # (B,nc,Q,H) inclusive
    # intra-chunk (quadratic in Q): L[t,s] = exp(cs_t - cs_s) for t ≥ s
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    att = jnp.einsum("bctn,bcsn,bctsh->bchts", Cc, Bc, L)
    y_diag = jnp.einsum("bchts,bcshp->bcthp", att, xc)

    # chunk-final states: decay from position s to chunk end
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)         # (B,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_end, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])             # (B,nc,H)

    def body(h, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        h_new = st + dec[..., None, None] * h
        return h_new, h                                # emit state *entering*

    h0 = jnp.zeros((B, H, Pd, N), F32)
    h_last, h_in = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                    # (B,nc,H,P,N)

    y_off = jnp.einsum("bctn,bchpn,bcth->bcthp", Cc, h_in, jnp.exp(cs))
    y = (y_diag + y_off).reshape(B, S, H, Pd)
    return y[:, :S0], h_last


def mamba2_mixer(cfg: ModelConfig, p, x, ctx: ShardCtx, return_state=False):
    """x (B,S,D) seq-sharded → (B,S,D) seq-sharded (full train/prefill)."""
    s = cfg.ssm
    x = ctx.constrain(x, ("batch", None, None))        # gather seq (SP)
    B, S, D = x.shape
    C = cfg.d_inner
    H, Pd = C // s.head_dim, s.head_dim

    z, xs, Bm, Cm, dt = _mamba2_inputs(cfg, p, x)
    xs_pre, Bm_pre, Cm_pre = xs, Bm, Cm               # pre-conv (decode state)
    xs = jax.nn.silu(causal_conv(xs, p["conv_x"], p["conv_x_b"]))
    Bm = jax.nn.silu(causal_conv(Bm, p["conv_B"], p["conv_B_b"]))
    Cm = jax.nn.silu(causal_conv(Cm, p["conv_C"], p["conv_C_b"]))
    xs = ctx.constrain(xs, ("batch", None, "d_inner"))

    dt = jax.nn.softplus(dt + p["dt_bias"])            # (B,S,H) f32
    a = -jnp.exp(p["A_log"].astype(F32))               # (H,)
    xh = xs.reshape(B, S, H, Pd).astype(F32) * dt[..., None]
    y, h_last = ssd_scan(xh, dt * a, Bm, Cm, s.chunk)
    y = y + p["D_skip"][None, None, :, None] * xs.reshape(B, S, H, Pd).astype(F32)
    y = y.reshape(B, S, C).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = jnp.einsum("bsc,cd->bsd", y, p["wo"])
    out = ctx.constrain(out, ("batch", "seq", None))
    if not return_state:
        return out
    K = s.d_conv - 1
    state = {"conv_x": xs_pre[:, S - K:, :].astype(cfg.pdtype),
             "conv_B": Bm_pre[:, S - K:, :].astype(cfg.pdtype),
             "conv_C": Cm_pre[:, S - K:, :].astype(cfg.pdtype),
             "ssm": h_last}
    return out, state


def mamba2_step(cfg: ModelConfig, p, xt, state, ctx: ShardCtx):
    """Decode step. xt (B,D); state dict with conv_{x,B,C} + ssm (B,H,P,N)."""
    s = cfg.ssm
    C = cfg.d_inner
    H, Pd = C // s.head_dim, s.head_dim
    z, xs, Bm, Cm, dt = _mamba2_inputs(cfg, p, xt[:, None, :])
    z, xs, Bm, Cm, dt = z[:, 0], xs[:, 0], Bm[:, 0], Cm[:, 0], dt[:, 0]
    st_x, xs = conv_step(state["conv_x"], xs, p["conv_x"], p["conv_x_b"])
    st_B, Bm = conv_step(state["conv_B"], Bm, p["conv_B"], p["conv_B_b"])
    st_C, Cm = conv_step(state["conv_C"], Cm, p["conv_C"], p["conv_C_b"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(F32)          # (B,H)
    a = -jnp.exp(p["A_log"].astype(F32))
    da = jnp.exp(dt * a)                                         # (B,H)
    xh = xs.reshape(-1, H, Pd).astype(F32) * dt[..., None]
    h = state["ssm"] * da[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bm.astype(F32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(F32), h)
    y = y + p["D_skip"][None, :, None] * xs.reshape(-1, H, Pd).astype(F32)
    y = y.reshape(-1, C).astype(xt.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = jnp.einsum("bc,cd->bd", y, p["wo"])
    new_state = {"conv_x": st_x, "conv_B": st_B, "conv_C": st_C, "ssm": h}
    return out, new_state


def mamba2_state_defs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    C = cfg.d_inner
    H, Pd = C // s.head_dim, s.head_dim
    K = s.d_conv - 1
    return {
        "conv_x": pd((batch, K, C), ("batch", "conv", "d_inner"), init="zeros",
                     dtype=cfg.pdtype),
        "conv_B": pd((batch, K, s.d_state), ("batch", "conv", "ssm_state"),
                     init="zeros", dtype=cfg.pdtype),
        "conv_C": pd((batch, K, s.d_state), ("batch", "conv", "ssm_state"),
                     init="zeros", dtype=cfg.pdtype),
        "ssm": pd((batch, H, Pd, s.d_state),
                  ("batch", "ssm_heads", None, None), init="zeros",
                  dtype=jnp.float32),
    }


# ------------------------------------------------------------------ mamba1
def mamba1_defs(cfg: ModelConfig):
    s = cfg.ssm
    D, C, N = cfg.d_model, cfg.d_inner, s.d_state
    dt_rank = max(1, -(-cfg.d_model // 16))
    out_scale = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wz": pd((D, C), ("embed", "d_inner"), dtype=cfg.pdtype),
        "wx": pd((D, C), ("embed", "d_inner"), dtype=cfg.pdtype),
        "conv_x": pd((s.d_conv, C), ("conv", "d_inner"), scale=0.1,
                     dtype=cfg.pdtype),
        "conv_x_b": pd((C,), ("d_inner",), init="zeros", dtype=cfg.pdtype),
        "w_bcdt": pd((C, dt_rank + 2 * N), ("d_inner", None), dtype=cfg.pdtype),
        "w_dt": pd((dt_rank, C), (None, "d_inner"), dtype=cfg.pdtype),
        "dt_bias": pd((C,), ("d_inner",), init="zeros", dtype=jnp.float32),
        "A_log": pd((C, N), ("d_inner", "ssm_state"), init="zeros",
                    dtype=jnp.float32),
        "D_skip": pd((C,), ("d_inner",), init="ones", dtype=jnp.float32),
        "wo": pd((C, D), ("d_inner", "embed"), scale=out_scale,
                 dtype=cfg.pdtype),
    }


def _mamba1_inputs(cfg, p, x):
    s = cfg.ssm
    N = s.d_state
    dt_rank = p["w_dt"].shape[0]
    z = jnp.einsum("bsd,dc->bsc", x, p["wz"])
    xs = jnp.einsum("bsd,dc->bsc", x, p["wx"])
    return z, xs, dt_rank, N


def _mamba1_ssm_params(cfg, p, xs):
    """xs: post-conv (B,S,C) → dt (B,S,C) f32, Bm/Cm (B,S,N) f32."""
    s = cfg.ssm
    N = s.d_state
    dt_rank = p["w_dt"].shape[0]
    bcdt = jnp.einsum("bsc,cr->bsr", xs, p["w_bcdt"])
    dt_r, Bm, Cm = (bcdt[..., :dt_rank], bcdt[..., dt_rank:dt_rank + N],
                    bcdt[..., dt_rank + N:])
    dt = jnp.einsum("bsr,rc->bsc", dt_r, p["w_dt"]).astype(F32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    return dt, Bm.astype(F32), Cm.astype(F32)


def mamba1_mixer(cfg: ModelConfig, p, x, ctx: ShardCtx, return_state=False):
    """Selective scan, chunked: associative scan within chunks, lax.scan across."""
    s = cfg.ssm
    x = ctx.constrain(x, ("batch", None, None))
    B, S, D = x.shape
    C, N = cfg.d_inner, s.d_state
    z, xs, _, _ = _mamba1_inputs(cfg, p, x)
    xs_pre = xs
    xs = jax.nn.silu(causal_conv(xs, p["conv_x"], p["conv_x_b"]))
    xs = ctx.constrain(xs, ("batch", None, "d_inner"))
    dt, Bm, Cm = _mamba1_ssm_params(cfg, p, xs)
    A = -jnp.exp(p["A_log"].astype(F32))                    # (C,N)

    Q = min(s.chunk, S)
    S0 = S
    pad = (-S) % Q
    xs_f, dt_f, Bm_f, Cm_f = xs.astype(F32), dt, Bm, Cm
    if pad:  # zero x + dt → a=exp(0)=1, b=0: exact no-op steps
        xs_f = jnp.pad(xs_f, ((0, 0), (0, pad), (0, 0)))
        dt_f = jnp.pad(dt_f, ((0, 0), (0, pad), (0, 0)))
        Bm_f = jnp.pad(Bm_f, ((0, 0), (0, pad), (0, 0)))
        Cm_f = jnp.pad(Cm_f, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    xf = xs_f.reshape(B, nc, Q, C)
    dtc = dt_f.reshape(B, nc, Q, C)
    Bc = Bm_f.reshape(B, nc, Q, N)
    Cc = Cm_f.reshape(B, nc, Q, N)

    def chunk_body(h, inp):
        xq, dq, bq, cq = inp                                # (B,Q,C) … (B,Q,N)
        da = jnp.exp(dq[..., None] * A)                     # (B,Q,C,N)
        u = (dq * xq)[..., None] * bq[:, :, None, :]        # (B,Q,C,N)
        # fold incoming state into the first step
        u = u.at[:, 0].add(da[:, 0] * h)
        a_all, h_all = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], r[0] * l[1] + r[1]), (da, u), axis=1)
        y = jnp.einsum("bqcn,bqn->bqc", h_all, cq)
        return h_all[:, -1], y

    chunk_body = jax.checkpoint(chunk_body)
    h0 = jnp.zeros((B, C, N), F32)
    h_last, ys = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, C)[:, :S0]
    y = y + p["D_skip"] * xs.astype(F32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["wo"])
    out = ctx.constrain(out, ("batch", "seq", None))
    if not return_state:
        return out
    K = s.d_conv - 1
    state = {"conv_x": xs_pre[:, S - K:, :].astype(cfg.pdtype), "ssm": h_last}
    return out, state


def mamba1_step(cfg: ModelConfig, p, xt, state, ctx: ShardCtx):
    """Decode step. xt (B,D); state: conv_x (B,K-1,C), ssm (B,C,N)."""
    s = cfg.ssm
    C, N = cfg.d_inner, s.d_state
    z, xs, _, _ = _mamba1_inputs(cfg, p, xt[:, None, :])
    z, xs = z[:, 0], xs[:, 0]
    st_x, xs = conv_step(state["conv_x"], xs, p["conv_x"], p["conv_x_b"])
    xs = jax.nn.silu(xs)
    dt, Bm, Cm = _mamba1_ssm_params(cfg, p, xs[:, None, :])
    dt, Bm, Cm = dt[:, 0], Bm[:, 0], Cm[:, 0]                # (B,C),(B,N)
    A = -jnp.exp(p["A_log"].astype(F32))
    da = jnp.exp(dt[..., None] * A)                          # (B,C,N)
    h = state["ssm"] * da + (dt * xs.astype(F32))[..., None] * Bm[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, Cm)
    y = y + p["D_skip"] * xs.astype(F32)
    y = y.astype(xt.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bc,cd->bd", y, p["wo"])
    return out, {"conv_x": st_x, "ssm": h}


def mamba1_state_defs(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    C, N = cfg.d_inner, s.d_state
    return {
        "conv_x": pd((batch, s.d_conv - 1, C), ("batch", "conv", "d_inner"),
                     init="zeros", dtype=cfg.pdtype),
        "ssm": pd((batch, C, N), ("batch", "d_inner", None), init="zeros",
                  dtype=jnp.float32),
    }


def mamba_defs(cfg: ModelConfig):
    return mamba2_defs(cfg) if cfg.ssm.version == 2 else mamba1_defs(cfg)


def mamba_mixer(cfg: ModelConfig, p, x, ctx: ShardCtx):
    fn = mamba2_mixer if cfg.ssm.version == 2 else mamba1_mixer
    return fn(cfg, p, x, ctx)
