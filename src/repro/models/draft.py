"""Big/little draft-model helpers for speculative decoding.

Speculative decoding (DESIGN.md §7) needs a *draft* model that (a) shares
the target's tokenizer/vocab, (b) is much cheaper per step, and (c) agrees
with the target often enough that verification accepts long prefixes. The
canonical way to get such a pair without training anything is **layer
truncation**: the draft is the target's first ``n_layers`` blocks plus the
target's own embed / final-norm / unembed, so early-layer representations —
which already carry most next-token information — drive the proposals.

``draft_from_target`` builds exactly that pair by slicing the stacked layer
leaves, sharing (not copying) the embedding tables. ``soften_deep_layers``
is the benchmark-side complement: it damps the *residual contributions* of
the deep layers (everything the draft does not have) by scaling their
output projections, which raises draft/target agreement to a realistic
high-acceptance regime while keeping the two models genuinely different.
Both helpers require a *uniform* layer stack (one schedule segment with a
single-block pattern) — truncating a hybrid/periodic schedule would change
which block kind sits at each depth, silently breaking alignment, so we
refuse instead.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import layer_schedule


def _uniform_stack(cfg: ModelConfig):
    """The single stacked segment of a uniform decoder, or raise."""
    if cfg.enc_dec:
        raise ValueError(f"{cfg.name}: draft truncation is decoder-only")
    segs = layer_schedule(cfg)
    if len(segs) != 1 or len(segs[0].pattern) != 1:
        raise ValueError(
            f"{cfg.name}: draft truncation needs a uniform layer stack "
            f"(got {len(segs)} segments); build the draft params explicitly "
            "for periodic/hybrid schedules")
    return segs[0]


def draft_from_target(cfg: ModelConfig, params, n_layers: int,
                      *, name: str | None = None):
    """(draft_cfg, draft_params): the target's first ``n_layers`` blocks.

    The draft shares the target's embed table, final norm and unembed
    *by reference* (no copies — they are the same arrays), so the pair is
    vocab-aligned by construction, as `Engine(draft_cfg=…)` requires.
    """
    seg = _uniform_stack(cfg)
    if not 1 <= n_layers < cfg.n_layers:
        raise ValueError(f"draft n_layers {n_layers} must be in "
                         f"[1, {cfg.n_layers})")
    draft_cfg = dataclasses.replace(
        cfg, name=name or f"{cfg.name}-draft{n_layers}", n_layers=n_layers)
    dsegs = layer_schedule(draft_cfg)
    if len(dsegs) != 1 or dsegs[0].pattern != seg.pattern:
        raise ValueError(f"{cfg.name}: truncated schedule is not a prefix "
                         "of the target schedule")
    blocks = [jax.tree.map(lambda x: x[:n_layers], params["blocks"][0])]
    dparams = {"embed": params["embed"], "blocks": blocks,
               "final_norm": params["final_norm"],
               "unembed": params["unembed"]}
    return draft_cfg, dparams


def soften_deep_layers(cfg: ModelConfig, params, n_keep: int,
                       alpha: float = 0.25):
    """Scale the residual output projections of layers ≥ ``n_keep``.

    Every block writes into the residual stream through exactly two
    projections — the attention output ``wo`` and the MLP ``w_down`` —
    so scaling those by ``alpha`` damps the deep layers' contribution
    without touching their inputs. With ``alpha`` well below 1 the
    first ``n_keep`` layers dominate the logits, so a draft built from
    them (``draft_from_target``) agrees with this softened target at a
    high-but-imperfect rate: the regime speculative decoding is for.
    Returns a new params tree; the input is unchanged.
    """
    _uniform_stack(cfg)
    if not 0 < n_keep <= cfg.n_layers:
        raise ValueError(f"n_keep {n_keep} out of range")

    def scale(path, x):
        leaf = path[-1]
        key = getattr(leaf, "key", getattr(leaf, "name", None))
        if key not in ("wo", "w_down"):
            return x
        deep = jnp.arange(x.shape[0]) >= n_keep
        mask = deep.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(mask, (x.astype(jnp.float32) * alpha).astype(x.dtype),
                         x)

    blocks = [jax.tree_util.tree_map_with_path(scale, params["blocks"][0])]
    return {**params, "blocks": blocks}
