"""Shared layer primitives: norms, rope, activations, dense MLP, embedding,
and the chunked (memory-bounded) cross-entropy loss.

Sharding conventions (DESIGN.md §3):
  residual stream   (B, S, D)  →  ("batch", "seq", None)    seq-sharded (SP)
  attention / mlp internals     →  heads / mlp hidden over "model"
All constraints go through ``ShardCtx`` so a 1-device mesh is a no-op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import ShardCtx
from repro.sharding.params import pd

F32 = jnp.float32


# ----------------------------------------------------------------- norms
def rmsnorm_def(dim: int):
    return pd((dim,), (None,), init="ones", dtype=jnp.float32)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


# ------------------------------------------------------------------ rope
def rope_tables(positions: jax.Array, dim: int, theta: float):
    """positions (…,) int → cos/sin (…, dim/2) fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, dh); cos/sin (S, dh/2) or (B, S, dh/2). NeoX half-rotation."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    if cos.ndim == 2:  # (S, half) → broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# ------------------------------------------------------------ activations
def activation(name: str):
    if name == "swiglu" or name == "geglu":
        raise ValueError("gated activations handled inside mlp()")
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def is_gated(act: str) -> bool:
    return act in ("swiglu", "geglu")


def gate_fn(act: str):
    return jax.nn.silu if act == "swiglu" else (
        lambda x: jax.nn.gelu(x, approximate=True))


# ------------------------------------------------------------- dense MLP
def mlp_defs(cfg: ModelConfig, d_ff: int, n_layers_hint: int = 1):
    out_scale = 0.02 / max(1.0, (2 * max(cfg.n_layers, 1)) ** 0.5)
    d = {"w_up": pd((cfg.d_model, d_ff), ("embed", "mlp"), dtype=cfg.pdtype),
         "w_down": pd((d_ff, cfg.d_model), ("mlp", "embed"), scale=out_scale,
                      dtype=cfg.pdtype)}
    if is_gated(cfg.act):
        d["w_gate"] = pd((cfg.d_model, d_ff), ("embed", "mlp"), dtype=cfg.pdtype)
    return d


def _gather_fsdp(x, spec, keep=("model",)):
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for ax in reversed(axes):
            if ax not in keep:
                x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
    return x


def mlp(cfg: ModelConfig, p, x: jax.Array, ctx: ShardCtx,
        d_ff: int | None = None) -> jax.Array:
    """x (B, S, D) seq-sharded → (B, S, D) seq-sharded.

    Megatron column/row TP + sequence parallelism with *explicit* shard_map
    collectives: all-gather(bf16 x) → local matmuls → psum-scatter(bf16).
    (GSPMD left to its own devices hoists the gather into the fp32 norm
    internals and emits all-reduce instead of reduce-scatter — measured 3×
    wire overhead; EXPERIMENTS.md §Perf iteration 3.)"""
    if ctx.axis_size("model") == 1:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        if is_gated(cfg.act):
            h = gate_fn(cfg.act)(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * h
        else:
            h = activation(cfg.act)(h)
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])

    from jax.experimental.shard_map import shard_map
    xspec = ctx.spec(("batch", "seq", None), x.shape)
    seq_sharded = xspec[1] is not None      # decode S=1 → replicated path
    pspecs = {n: ctx.spec(("embed", "mlp") if n != "w_down" else
                          ("mlp", "embed"), p[n].shape) for n in p}

    def local(x_loc, params):
        xg = jax.lax.all_gather(x_loc, "model", axis=1, tiled=True) \
            if seq_sharded else x_loc
        w_up = _gather_fsdp(params["w_up"], pspecs["w_up"])
        h = jnp.einsum("bsd,df->bsf", xg, w_up)
        if is_gated(cfg.act):
            w_gate = _gather_fsdp(params["w_gate"], pspecs["w_gate"])
            h = gate_fn(cfg.act)(jnp.einsum("bsd,df->bsf", xg, w_gate)) * h
        else:
            h = activation(cfg.act)(h)
        w_down = _gather_fsdp(params["w_down"], pspecs["w_down"])
        out = jnp.einsum("bsf,fd->bsd", h, w_down)
        if seq_sharded:
            return jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(out, "model")

    fn = shard_map(local, mesh=ctx.mesh,
                   in_specs=(xspec, {n: pspecs[n] for n in p}),
                   out_specs=xspec, check_rep=False)
    return fn(x, dict(p))


# -------------------------------------------------------------- embedding
def embed_defs(cfg: ModelConfig):
    d = {"table": pd((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                     scale=0.02, dtype=cfg.pdtype)}
    if cfg.frontend != "none" and cfg.frontend_dim:
        d["frontend_proj"] = pd((cfg.frontend_dim, cfg.d_model),
                                ("frontend", "embed"), dtype=cfg.pdtype)
    return d


def embed(cfg: ModelConfig, p, tokens: jax.Array, ctx: ShardCtx,
          frontend_embed: jax.Array | None = None) -> jax.Array:
    """tokens (B, S) → (B, S, D). VLM: first `frontend_tokens` positions are
    replaced by projected patch embeddings (tokens there are a pad id)."""
    h = jnp.take(p["table"], tokens, axis=0).astype(cfg.pdtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if frontend_embed is not None:
        fe = jnp.einsum("bfe,ed->bfd", frontend_embed.astype(cfg.pdtype),
                        p["frontend_proj"])
        if cfg.embed_scale:
            fe = fe * jnp.asarray(cfg.d_model ** 0.5, fe.dtype)
        h = jnp.concatenate([fe, h[:, fe.shape[1]:, :]], axis=1)
    return ctx.constrain(h, ("batch", "seq", None))


# ------------------------------------------------- unembed + chunked loss
def unembed_defs(cfg: ModelConfig):
    if cfg.tie_embeddings:
        return {}
    return {"w": pd((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                    dtype=cfg.pdtype)}


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def logits_fn(cfg: ModelConfig, embed_p, unembed_p, h, ctx: ShardCtx):
    """h (B, T, D) → logits (B, T, V) fp32, vocab-sharded."""
    w = embed_p["table"].T if cfg.tie_embeddings else unembed_p["w"]
    logits = jnp.einsum("btd,dv->btv", h, w.astype(h.dtype),
                        preferred_element_type=F32)
    logits = _softcap(logits, cfg.final_softcap)
    return ctx.constrain(logits, ("batch", None, "vocab"))


def chunked_ce_loss(cfg: ModelConfig, embed_p, unembed_p, h, targets, mask,
                    ctx: ShardCtx, chunk: int = 512):
    """Cross-entropy without materialising (B, S, V).

    h (B, S, D) seq-sharded. Scans over sequence chunks; logits stay
    vocab-sharded; the label logit is extracted with a sharded one-hot
    contraction (no cross-shard gather). Returns (sum_loss, sum_count).
    """
    h = ctx.constrain(h, ("batch", None, None))  # all-gather seq for chunking
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def chunk_loss(hc, tc, mc):
        logits = logits_fn(cfg, embed_p, unembed_p, hc, ctx)     # (B,c,V) f32
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.squeeze(m, -1) + jnp.log(
            jnp.sum(jnp.exp(logits - m), axis=-1))
        onehot = jax.nn.one_hot(tc, cfg.vocab, dtype=logits.dtype)
        onehot = ctx.constrain(onehot, ("batch", None, "vocab"))
        lab = jnp.sum(logits * onehot, axis=-1)
        loss = (lse - lab) * mc
        return jnp.sum(loss), jnp.sum(mc)

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(carry, xs):
        sl, sc = carry
        l, c = chunk_loss(*xs)
        return (sl + l, sc + c), None

    hs = h[:, :n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    ts = targets[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (sum_l, sum_c), _ = jax.lax.scan(
        body, (jnp.zeros((), F32), jnp.zeros((), F32)), (hs, ts, ms))
    if rem:
        l, c = chunk_loss(h[:, n * chunk:], targets[:, n * chunk:],
                          mask[:, n * chunk:])
        sum_l, sum_c = sum_l + l, sum_c + c
    return sum_l, sum_c
