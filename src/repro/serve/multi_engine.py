"""Multi-engine heterogeneous serving — the paper's CC/FC pool at request
granularity.

The paper's core result (§6) is that a dynamic scheduler distributing one
workload across *all* device classes — CPU cores assisting the FPGA —
beats pure offload. :class:`MultiEngine` is that scheduler at serving
granularity: it owns N heterogeneous :class:`~repro.serve.engine.Engine`
tiers (e.g. a paged-kernel compiled decode tier plus a CPU/interpret tier,
or big/little model tiers) under ONE shared
:class:`~repro.core.tracker.ThroughputTracker`, and routes submitted
requests across them with the same ``proportional_split`` law the HBB
static/oracle schedulers use — per-tier *measured* tok/s over token-unit
cost (:mod:`repro.serve.scheduler`).

Mapping onto the paper's two-stage pipeline (Fig. 1):

* **S1 (dispatch)** — each global cycle, the queued requests are split
  over the tiers in proportion to their effective speeds, capped by each
  tier's admission capacity (free slots; paged tiers additionally their
  pool's worst-case commit budget via ``Engine.plan_admission``).
* **S2 (accounting)** — each tier's :class:`~repro.serve.engine.StepReport`
  feeds ``(decoded tokens, quantum seconds)`` of warm cycles into the
  shared tracker, which is what the next S1 round measures speeds from.

Work conservation: a tier that stalls or whose pool exhausts simply has no
capacity, so its share spills to the live tiers; whatever a tier's own
admission law could not take this cycle is reclaimed (``take_pending``)
into the global queue and rerouted next cycle. Queued work is never
blocked behind a dead tier.

Tiers with ``concurrent=True`` (default) step in parallel threads — the
serving analogue of the paper's resources running simultaneously; each
engine is only ever touched by one thread per cycle, engines share the
(read-only) parameter tree, and the shared tracker is lock-guarded. At
``temperature=0`` every tier built over the same parameters decodes the
same greedy stream, so a request's output is independent of the tier that
served it (asserted by ``tests/test_multi_engine.py`` and BENCH_3).

Speculative big/little tiers (DESIGN.md §7) compose under the same law
with no scheduler changes: a draft-assisted tier's ``StepReport.decoded``
counts *emitted* (accepted) tokens, never draft proposals or verify
rounds, so the shared tracker measures its **effective** tok/s — raw
verify-round rate × (accepted / round). A spec tier whose drafts are
being rejected automatically earns a smaller share of the queue; one
whose drafts land earns more. The per-tier accepted/proposed tallies are
surfaced through :meth:`MultiEngine.stats` for acceptance-rate reporting.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import jax

from repro.configs.base import ModelConfig
from repro.core.tracker import ThroughputTracker
from repro.models.model import model_defs
from repro.serve.engine import (Engine, EngineStallError, PromptTooLongError,
                                Request, StepReport)
from repro.serve.scheduler import request_units, route_requests, tier_speeds
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx


@dataclass
class EngineTier:
    """One resource of the serving pool: an engine plus its routing traits.

    Attributes:
      name: unique tier label (tracker resource name, routing logs).
      engine: the :class:`~repro.serve.engine.Engine` serving this tier.
      kind: tracker classification, ``"accelerator"`` or ``"core"`` —
        the paper's FC vs CC device classes (reporting only; routing uses
        measured speeds, not the class).
      unit_cost: relative cost of one token on this tier (energy, $/hour,
        contention). Routing divides measured tok/s by it, so a tier twice
        as expensive earns half the share its raw speed would.
      prior_tok_s: routing speed assumed until the shared tracker has a
        warm measurement for this tier (the ``f0`` analogue).
    """
    name: str
    engine: Engine
    kind: str = "core"
    unit_cost: float = 1.0
    prior_tok_s: float = 1.0
    routed: int = field(default=0, init=False)      # requests sent here
    decoded: int = field(default=0, init=False)     # tokens emitted here
    accepted: int = field(default=0, init=False)    # spec: draft tokens kept
    proposed: int = field(default=0, init=False)    # spec: draft tokens tried


class MultiEngine:
    """N heterogeneous Engine tiers behind one submit/step/run surface.

    See the module docstring for the scheduling model. Construction
    validates the pool: at least one tier, unique names, distinct engine
    objects (an engine donates its cache through its decode loop — sharing
    one between tiers would alias donated buffers).
    """

    def __init__(self, tiers: list[EngineTier], *, concurrent: bool = True):
        if not tiers:
            raise ValueError("MultiEngine needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        engines = [t.engine for t in tiers]
        if len({id(e) for e in engines}) != len(engines):
            raise ValueError("tiers must not share an Engine object (its "
                             "decode loop donates the cache)")
        for t in tiers:
            if t.kind not in ("accelerator", "core"):
                raise ValueError(f"tier {t.name}: kind must be "
                                 f"'accelerator' or 'core', got {t.kind!r}")
            if t.unit_cost <= 0 or t.prior_tok_s <= 0:
                raise ValueError(f"tier {t.name}: unit_cost and prior_tok_s "
                                 "must be positive")
        self.tiers = list(tiers)
        self.tracker = ThroughputTracker({t.name: t.kind for t in tiers})
        self.queue: list[Request] = []
        # rid → tier name, written at routing time. Reporting surface (the
        # bench and tests read it after run()); entries persist for the
        # pool's lifetime — a long-lived caller that recycles rids can
        # clear it between batches.
        self.assigned: dict[int, str] = {}
        self.cycle_log: list[dict] = []
        self.cycles = 0
        self._pool = (ThreadPoolExecutor(max_workers=len(tiers),
                                         thread_name_prefix="tier")
                      if concurrent and len(tiers) > 1 else None)

    # ---- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Raises :class:`PromptTooLongError` only when NO
        tier can ever hold the prompt — a prompt too long for one tier is
        simply ineligible there and routes to a longer-context tier."""
        n = len(req.prompt)
        if n == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if all(n >= t.engine.max_len for t in self.tiers):
            raise PromptTooLongError(
                f"request {req.rid}: prompt of {n} tokens exceeds every "
                f"tier's max_len "
                f"({[t.engine.max_len for t in self.tiers]})")
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(t.engine.has_work()
                                       for t in self.tiers)

    # ---- S1: route -------------------------------------------------------
    def _route(self) -> dict[str, int]:
        """Split the global queue across tiers (proportional_split over
        measured speeds, capacity- and eligibility-capped) and push each
        tier's slice into its pending queue. Returns per-tier counts.

        A tier can refuse part of its slice (``plan_admission``: pool
        cannot commit the worst case). Refused requests mark that tier
        ineligible for the rest of this cycle and the remainder re-routes
        immediately — otherwise a pool-exhausted tier that *looks* fast to
        the proportional law would win the same request every cycle and
        starve it while other tiers idle (work conservation)."""
        routed = {t.name: 0 for t in self.tiers}
        if not self.queue:
            return routed
        speeds = tier_speeds(
            [self.tracker.throughput(t.name) for t in self.tiers],
            [t.prior_tok_s for t in self.tiers],
            [t.unit_cost for t in self.tiers])
        blocked: dict[int, set[int]] = {}       # id(req) → refusing tiers
        for _ in range(len(self.tiers)):
            queue = self.queue
            units = [request_units(len(r.prompt), r.max_new) for r in queue]
            caps = [max(0, len(t.engine.free_slots()) - len(t.engine.pending))
                    for t in self.tiers]
            eligible = [[len(r.prompt) < t.engine.max_len
                         and i not in blocked.get(id(r), ())
                         for i, t in enumerate(self.tiers)] for r in queue]
            assign = route_requests(units, speeds, caps, eligible)
            taken: set[int] = set()
            refused = False
            for i, (tier, idxs) in enumerate(zip(self.tiers, assign)):
                reqs = [queue[j] for j in idxs]
                k = tier.engine.plan_admission(reqs)
                for req in reqs[:k]:
                    tier.engine.submit(req)
                    self.assigned[req.rid] = tier.name
                    tier.routed += 1
                    routed[tier.name] += 1
                    taken.add(id(req))
                for req in reqs[k:]:
                    blocked.setdefault(id(req), set()).add(i)
                    refused = True
            if taken:
                self.queue = [r for r in self.queue if id(r) not in taken]
            if not refused or not self.queue:
                break
        return routed

    # ---- one global cycle ------------------------------------------------
    def step(self) -> dict[str, StepReport]:
        """One pool cycle: route (S1), step every tier with work — in
        parallel threads when ``concurrent`` — then record warm throughput
        samples into the shared tracker (S2) and reclaim whatever each
        tier's own admission law left pending."""
        # arrival order of this cycle's queue: reclaimed leftovers were
        # routed from it, so this is enough to restore global FIFO after
        # they come back (requests submitted directly to a tier's engine
        # were never in the queue — they join at the tail, stably)
        order = {id(r): i for i, r in enumerate(self.queue)}
        routed = self._route()
        busy = [t for t in self.tiers if t.engine.has_work()]
        if self._pool is not None and len(busy) > 1:
            reports = list(self._pool.map(lambda t: t.engine.step(), busy))
        else:
            reports = [t.engine.step() for t in busy]
        out: dict[str, StepReport] = {}
        for tier, rep in zip(busy, reports):
            out[tier.name] = rep
            tier.decoded += rep.decoded
            tier.accepted += rep.accepted
            tier.proposed += rep.proposed
            # decoded counts *emissions* (for spec tiers: accepted tokens,
            # never rounds or proposals), so the tracker's tok/s is the
            # acceptance-scaled effective speed the router needs
            if rep.decoded and rep.warm:
                self.tracker.record(tier.name, rep.decoded, rep.dt)
            leftovers = tier.engine.take_pending()
            if leftovers:
                for req in leftovers:       # back to global, reroutable
                    # only un-count requests this router actually placed —
                    # work submitted to the engine directly just joins the
                    # global queue without touching the tier's stats
                    if self.assigned.pop(req.rid, None) is not None:
                        tier.routed -= 1
                self.queue.extend(leftovers)
        if self.queue:
            self.queue.sort(key=lambda r: order.get(id(r), len(order)))
        self.cycles += 1
        self.cycle_log.append({
            "queued": len(self.queue),
            "routed": routed,
            "decoded": {t.name: out[t.name].decoded for t in busy},
        })
        return out

    # ---- drive to completion ---------------------------------------------
    def _guard_limit(self) -> int:
        """Aggregate of the per-engine guard: every request needs ≲ one
        admission cycle plus max_new/quantum decode cycles; 8× slack."""
        quantum = min((t.engine.decode_quantum if t.engine.fast else 1)
                      for t in self.tiers)
        reqs = list(self.queue)
        for t in self.tiers:
            reqs += t.engine.pending
            reqs += [r for r in t.engine.slot_req if r is not None]
        tokens = sum(max(1, r.max_new) for r in reqs)
        return 64 + 8 * (len(reqs) + -(-tokens // quantum))

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion across the pool. Raises
        :class:`EngineStallError` with per-tier diagnostics if the pool
        stops making progress (scheduling bug or global starvation)."""
        for r in requests:
            self.submit(r)
        guard, limit = 0, self._guard_limit()
        while self.has_work():
            if guard >= limit:
                raise EngineStallError(
                    f"multi-engine made no progress after {guard} cycles "
                    f"(limit {limit}): {len(self.queue)} queued; "
                    + "; ".join(self._tier_diag(t) for t in self.tiers))
            self.step()
            guard += 1
        return requests

    def drain(self) -> None:
        """Finish all admitted and queued work without new submissions."""
        self.run([])

    def _tier_diag(self, tier: EngineTier) -> str:
        eng = tier.engine
        busy = sum(1 for r in eng.slot_req if r is not None)
        d = (f"{tier.name}: {len(eng.pending)} pending, {busy}/"
             f"{eng.max_slots} slots busy")
        if eng.paged:
            d += f", {len(eng.alloc.free)} pages free"
        return d

    # ---- reporting -------------------------------------------------------
    def stats(self) -> dict:
        """Aggregated completion/throughput report across tiers."""
        snap = self.tracker.snapshot()
        tiers = {}
        for t in self.tiers:
            s = snap[t.name]
            tiers[t.name] = {
                "kind": t.kind,
                "routed": t.routed,
                "decoded": t.decoded,
                "accepted": t.accepted,
                "proposed": t.proposed,
                "acceptance": (t.accepted / t.proposed if t.proposed else 0.0),
                "tok_s": s.ewma_thr,
                "busy_time": s.busy_time,
                "unit_cost": t.unit_cost,
            }
        return {"cycles": self.cycles, "queued": len(self.queue),
                "tiers": tiers}


def make_multi_engine(cfg: ModelConfig, ctx: ShardCtx,
                      tier_kws: list[dict], *, seed: int = 0,
                      concurrent: bool = True, **shared_kw) -> MultiEngine:
    """Build a tier pool over ONE shared parameter set.

    Each dict in ``tier_kws`` holds that tier's Engine kwargs plus the
    optional routing keys ``name`` / ``kind`` / ``unit_cost`` /
    ``prior_tok_s``; ``shared_kw`` is merged under every tier (tier keys
    win). Sharing the materialized parameters is what makes the tiers
    token-equivalent at ``temperature=0`` — and costs one copy of the
    model, not N.

        meng = make_multi_engine(cfg, ctx, [
            {"name": "dense"},
            {"name": "paged", "paged": True, "page_size": 8},
        ], max_slots=4, max_len=128)

    A big/little speculative tier rides the same mechanism — pass that
    tier ``draft_cfg``/``draft_params``/``spec_k`` in its dict; at
    ``temperature=0`` its stream is token-identical to the plain tiers'
    (greedy spec-decode equivalence, DESIGN.md §7), so pool outputs stay
    tier-independent.
    """
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    tiers = []
    for i, kw in enumerate(tier_kws):
        kw = {**shared_kw, **kw}
        name = kw.pop("name", f"tier{i}")
        kind = kw.pop("kind", "core")
        unit_cost = kw.pop("unit_cost", 1.0)
        prior = kw.pop("prior_tok_s", 1.0)
        tiers.append(EngineTier(name, Engine(cfg, params, ctx, **kw),
                                kind=kind, unit_cost=unit_cost,
                                prior_tok_s=prior))
    return MultiEngine(tiers, concurrent=concurrent)
