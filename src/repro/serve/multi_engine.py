"""Multi-engine heterogeneous serving — the paper's CC/FC pool at request
granularity.

The paper's core result (§6) is that a dynamic scheduler distributing one
workload across *all* device classes — CPU cores assisting the FPGA —
beats pure offload. :class:`MultiEngine` is that scheduler at serving
granularity: it owns N heterogeneous :class:`~repro.serve.engine.Engine`
tiers (e.g. a paged-kernel compiled decode tier plus a CPU/interpret tier,
or big/little model tiers) under ONE shared
:class:`~repro.core.tracker.ThroughputTracker`, and routes submitted
requests across them with the same ``proportional_split`` law the HBB
static/oracle schedulers use — per-tier *measured* tok/s over token-unit
cost (:mod:`repro.serve.scheduler`).

Mapping onto the paper's two-stage pipeline (Fig. 1):

* **S1 (dispatch)** — each global cycle, the queued requests are split
  over the tiers in proportion to their effective speeds, capped by each
  tier's admission capacity (free slots; paged tiers additionally their
  pool's worst-case commit budget via ``Engine.plan_admission``).
* **S2 (accounting)** — each tier's :class:`~repro.serve.engine.StepReport`
  feeds ``(decoded tokens, quantum seconds)`` of warm cycles into the
  shared tracker, which is what the next S1 round measures speeds from.

Work conservation: a tier that stalls or whose pool exhausts simply has no
capacity, so its share spills to the live tiers; whatever a tier's own
admission law could not take this cycle is reclaimed (``take_pending``)
into the global queue and rerouted next cycle. Queued work is never
blocked behind a dead tier.

Tiers with ``concurrent=True`` (default) step in parallel threads — the
serving analogue of the paper's resources running simultaneously; each
engine is only ever touched by one thread per cycle, engines share the
(read-only) parameter tree, and the shared tracker is lock-guarded. At
``temperature=0`` every tier built over the same parameters decodes the
same greedy stream, so a request's output is independent of the tier that
served it (asserted by ``tests/test_multi_engine.py`` and BENCH_3).

Speculative big/little tiers (DESIGN.md §7) compose under the same law
with no scheduler changes: a draft-assisted tier's ``StepReport.decoded``
counts *emitted* (accepted) tokens, never draft proposals or verify
rounds, so the shared tracker measures its **effective** tok/s — raw
verify-round rate × (accepted / round). A spec tier whose drafts are
being rejected automatically earns a smaller share of the queue; one
whose drafts land earns more. The per-tier accepted/proposed tallies are
surfaced through :meth:`MultiEngine.stats` for acceptance-rate reporting.

Fault tolerance (DESIGN.md §8): the pool survives a *sick* tier the same
way it survives a slow one. A per-tier health state machine (healthy →
degraded → quarantined → probation, :class:`HealthPolicy`) is driven by
step failures — exceptions, corrupt :class:`StepReport`s, and a per-step
deadline watchdog (``future`` timeouts in concurrent mode, post-hoc wall
time in serial). Quarantining a tier reclaims its in-flight requests
(``take_pending`` + failure-safe ``Engine.abort``, pages released) and
re-routes them the same cycle through the ordinary scheduler law with the
sick tier's capacity masked to zero (:func:`repro.serve.scheduler.
apply_health`); each reclaimed request re-prefills from its original
prompt plus already-emitted tokens (:func:`repro.serve.decode.
plan_resume`), so greedy recovery streams are token-identical to an
unfailed run. Retries are budgeted with exponential backoff; a request
that exhausts its budget is dead-lettered
(:class:`~repro.serve.engine.RequestFailedError` in ``dead_letters``)
instead of poisoning the pool. After its hold, a quarantined tier
re-enters through probation: one canary request until
``probation_steps`` clean steps restore its full share.
"""
from __future__ import annotations

import math
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass, field
from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.core.tracker import ThroughputTracker
from repro.models.model import model_defs
from repro.serve.decode import plan_resume
from repro.serve.engine import (Engine, EngineStallError, PromptTooLongError,
                                Request, RequestFailedError, StepReport)
from repro.serve.scheduler import (DEGRADED, HEALTHY, PROBATION, QUARANTINED,
                                   apply_health, request_units,
                                   route_requests, tier_speeds)
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx


@dataclass(frozen=True)
class HealthPolicy:
    """Knobs of the tier health supervisor and request retry law (§8).

    Attributes:
      quarantine_after: consecutive step failures that quarantine a tier.
        The first failure already marks it ``degraded`` (bookkeeping
        only — routing is unchanged, one transient fault must not shed
        load).
      quarantine_cycles: pool cycles a quarantined tier sits out before
        probation. Doubled (capped at 64) each time its probation canary
        fails — exponential backoff for a tier that keeps relapsing.
      probation_steps: clean steps a probation tier must serve (on its
        single canary request) before its full routing share is restored.
      retry_budget: failed attempts per *request* before it is
        dead-lettered with :class:`~repro.serve.engine.RequestFailedError`
        instead of retried again.
      retry_backoff: base pool-cycle delay before a failed request
        re-enters the queue; attempt ``k`` waits
        ``retry_backoff · 2^(k−1)`` cycles.
      step_deadline_s: pool-default per-step wall-clock deadline (None:
        none). A tier's own ``Engine.step_deadline_s`` takes precedence.
        In concurrent mode the watchdog times out the step's future; in
        serial mode the check is post-hoc (the step cannot be preempted,
        but a hung quantum still counts as a failure).
    """
    quarantine_after: int = 2
    quarantine_cycles: int = 2
    probation_steps: int = 2
    retry_budget: int = 3
    retry_backoff: int = 1
    step_deadline_s: float | None = None

    def __post_init__(self):
        if (self.quarantine_after < 1 or self.quarantine_cycles < 1
                or self.probation_steps < 1 or self.retry_budget < 0
                or self.retry_backoff < 0):
            raise ValueError(f"invalid HealthPolicy: {self}")
        if self.step_deadline_s is not None and self.step_deadline_s <= 0:
            raise ValueError("step_deadline_s must be positive or None, "
                             f"got {self.step_deadline_s}")


@dataclass
class EngineTier:
    """One resource of the serving pool: an engine plus its routing traits.

    Attributes:
      name: unique tier label (tracker resource name, routing logs).
      engine: the :class:`~repro.serve.engine.Engine` serving this tier.
      kind: tracker classification, ``"accelerator"`` or ``"core"`` —
        the paper's FC vs CC device classes (reporting only; routing uses
        measured speeds, not the class).
      unit_cost: relative cost of one token on this tier (energy, $/hour,
        contention). Routing divides measured tok/s by it, so a tier twice
        as expensive earns half the share its raw speed would.
      prior_tok_s: routing speed assumed until the shared tracker has a
        warm measurement for this tier (the ``f0`` analogue).
      health: supervisor state (scheduler.HEALTHY/DEGRADED/QUARANTINED/
        PROBATION); transitions are appended to ``MultiEngine.health_log``.
    """
    name: str
    engine: Engine
    kind: str = "core"
    unit_cost: float = 1.0
    prior_tok_s: float = 1.0
    routed: int = field(default=0, init=False)      # requests sent here
    decoded: int = field(default=0, init=False)     # tokens emitted here
    accepted: int = field(default=0, init=False)    # spec: draft tokens kept
    proposed: int = field(default=0, init=False)    # spec: draft tokens tried
    # ---- supervisor state (§8) -------------------------------------------
    health: str = field(default=HEALTHY, init=False)
    fail_streak: int = field(default=0, init=False)  # consecutive failures
    failures: int = field(default=0, init=False)     # lifetime failures
    reclaims: int = field(default=0, init=False)     # requests pulled back
    quarantined_at: int = field(default=-1, init=False)
    quarantine_len: int = field(default=0, init=False)
    probation_ok: int = field(default=0, init=False)
    # a step future that blew its deadline and is still running; the
    # engine is untouchable (its thread owns it) until the future is done
    inflight: Optional[object] = field(default=None, init=False)
    reclaimed: bool = field(default=True, init=False)


class MultiEngine:
    """N heterogeneous Engine tiers behind one submit/step/run surface.

    See the module docstring for the scheduling model. Construction
    validates the pool: at least one tier, unique names, distinct engine
    objects (an engine donates its cache through its decode loop — sharing
    one between tiers would alias donated buffers).
    """

    def __init__(self, tiers: list[EngineTier], *, concurrent: bool = True,
                 policy: HealthPolicy | None = None):
        if not tiers:
            raise ValueError("MultiEngine needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        engines = [t.engine for t in tiers]
        if len({id(e) for e in engines}) != len(engines):
            raise ValueError("tiers must not share an Engine object (its "
                             "decode loop donates the cache)")
        for t in tiers:
            if t.kind not in ("accelerator", "core"):
                raise ValueError(f"tier {t.name}: kind must be "
                                 f"'accelerator' or 'core', got {t.kind!r}")
            if t.unit_cost <= 0 or t.prior_tok_s <= 0:
                raise ValueError(f"tier {t.name}: unit_cost and prior_tok_s "
                                 "must be positive")
        self.tiers = list(tiers)
        self.tracker = ThroughputTracker({t.name: t.kind for t in tiers})
        self.queue: list[Request] = []
        # rid → tier name, written at routing time. Reporting surface (the
        # bench and tests read it after run()); entries persist for the
        # pool's lifetime — a long-lived caller that recycles rids can
        # clear it between batches.
        self.assigned: dict[int, str] = {}
        self.cycle_log: list[dict] = []
        self.cycles = 0
        self._pool = (ThreadPoolExecutor(max_workers=len(tiers),
                                         thread_name_prefix="tier")
                      if concurrent and len(tiers) > 1 else None)
        # ---- fault tolerance (§8) ----------------------------------------
        self.policy = policy or HealthPolicy()
        # rid → RequestFailedError for requests that exhausted their retry
        # budget (or were orphaned by a pool stall); the pool no longer
        # tracks them, run() does not raise for them
        self.dead_letters: dict[int, RequestFailedError] = {}
        # rid → original identity of a request being retried: we mutate the
        # caller's Request in place (prompt := prompt+out, budget shrunk)
        # and restore prompt/max_new/full stream when it terminates
        self._resume: dict[int, dict] = {}
        self._delayed: list[tuple[int, Request]] = []   # (ready_cycle, req)
        self.retries = 0                                # resubmitted streams
        self.health_log: list[dict] = []                # state transitions

    # ---- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Raises :class:`PromptTooLongError` only when NO
        tier can ever hold the prompt — a prompt too long for one tier is
        simply ineligible there and routes to a longer-context tier.

        Well-defined after a mid-run failure (§8): a Request *object*
        already queued, backing off for retry, or in flight on a tier is
        rejected with :class:`ValueError` (double-submitting it would
        alias one stream through two slots); a previously dead-lettered
        ``rid`` re-queues cleanly — the dead letter is cleared and the
        request is served fresh from its current fields. After ``run()``
        raised :class:`EngineStallError`, the pool is already reclaimed
        (no stale per-tier state), so new submissions start from a clean
        pool."""
        n = len(req.prompt)
        if n == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if all(n >= t.engine.max_len for t in self.tiers):
            raise PromptTooLongError(
                f"request {req.rid}: prompt of {n} tokens exceeds every "
                f"tier's max_len "
                f"({[t.engine.max_len for t in self.tiers]})")
        live = any(req is r for r in self.queue)
        live = live or any(req is r for _, r in self._delayed)
        for t in self.tiers:
            live = live or any(req is r for r in t.engine.pending)
            live = live or any(req is r for r in t.engine.slot_req
                               if r is not None)
        if live:
            raise ValueError(
                f"request {req.rid} is already queued or in flight — a "
                f"Request object is single-use until it terminates")
        self.dead_letters.pop(req.rid, None)   # resubmission clears it
        self._resume.pop(req.rid, None)        # and any stale retry state
        self.queue.append(req)

    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self._delayed)
                or any(t.inflight is not None for t in self.tiers)
                or any(t.engine.has_work() for t in self.tiers))

    # ---- S1: route -------------------------------------------------------
    def _route(self) -> dict[str, int]:
        """Split the global queue across tiers (proportional_split over
        measured speeds, capacity- and eligibility-capped) and push each
        tier's slice into its pending queue. Returns per-tier counts.

        A tier can refuse part of its slice (``plan_admission``: pool
        cannot commit the worst case). Refused requests mark that tier
        ineligible for the rest of this cycle and the remainder re-routes
        immediately — otherwise a pool-exhausted tier that *looks* fast to
        the proportional law would win the same request every cycle and
        starve it while other tiers idle (work conservation)."""
        routed = {t.name: 0 for t in self.tiers}
        if not self.queue:
            return routed
        speeds = tier_speeds(
            [self.tracker.throughput(t.name) for t in self.tiers],
            [t.prior_tok_s for t in self.tiers],
            [t.unit_cost for t in self.tiers])
        blocked: dict[int, set[int]] = {}       # id(req) → refusing tiers
        for _ in range(len(self.tiers)):
            queue = self.queue
            units = [request_units(len(r.prompt), r.max_new) for r in queue]
            caps = [max(0, len(t.engine.free_slots()) - len(t.engine.pending))
                    for t in self.tiers]
            # health mask (§8): quarantined tiers take nothing, probation
            # tiers at most one canary; a tier whose hung step still owns
            # its engine thread is untouchable regardless of health
            busy = [sum(1 for r in t.engine.slot_req if r is not None)
                    + len(t.engine.pending) for t in self.tiers]
            caps = apply_health(caps, [t.health for t in self.tiers], busy)
            caps = [0 if t.inflight is not None else c
                    for t, c in zip(self.tiers, caps)]
            eligible = [[len(r.prompt) < t.engine.max_len
                         and i not in blocked.get(id(r), ())
                         for i, t in enumerate(self.tiers)] for r in queue]
            assign = route_requests(units, speeds, caps, eligible)
            taken: set[int] = set()
            refused = False
            for i, (tier, idxs) in enumerate(zip(self.tiers, assign)):
                reqs = [queue[j] for j in idxs]
                try:
                    k = tier.engine.plan_admission(reqs)
                except Exception as e:           # a sick tier must not
                    self._observe_failure(tier,  # poison routing itself
                                          f"plan_admission raised: {e!r}")
                    k = 0
                for req in reqs[:k]:
                    tier.engine.submit(req)
                    self.assigned[req.rid] = tier.name
                    tier.routed += 1
                    routed[tier.name] += 1
                    taken.add(id(req))
                for req in reqs[k:]:
                    blocked.setdefault(id(req), set()).add(i)
                    refused = True
            if taken:
                self.queue = [r for r in self.queue if id(r) not in taken]
            if not refused or not self.queue:
                break
        return routed

    # ---- one global cycle ------------------------------------------------
    def step(self) -> dict[str, StepReport]:
        """One pool cycle: poll hung steps, advance health timers, release
        backed-off retries, route (S1), step every steppable tier with
        work — in parallel threads under the deadline watchdog when
        ``concurrent`` — then record *valid* warm throughput samples into
        the shared tracker (S2), apply health transitions, reclaim both
        admission leftovers and any newly quarantined tier's in-flight
        requests, and restore completed retried streams."""
        self._poll_inflight()
        self._advance_health()
        self._release_delayed()
        # arrival order of this cycle's queue: reclaimed leftovers were
        # routed from it, so this is enough to restore global FIFO after
        # they come back (requests submitted directly to a tier's engine
        # were never in the queue — they join at the tail, stably)
        order = {id(r): i for i, r in enumerate(self.queue)}
        routed = self._route()
        busy = [t for t in self.tiers
                if t.health != QUARANTINED and t.inflight is None
                and t.engine.has_work()]
        if not busy:
            # nothing steppable — if the pool is only waiting on a hung
            # step thread, block on it briefly instead of burning guard
            # cycles in a busy spin (the thread cannot be preempted; its
            # tier is reclaimed by _poll_inflight next cycle)
            for tier in self.tiers:
                if tier.inflight is not None:
                    try:
                        tier.inflight.result(timeout=0.25)
                    except Exception:
                        pass
                    break
        outcomes = self._step_tiers(busy)
        out: dict[str, StepReport] = {}
        for tier, (status, payload) in zip(busy, outcomes):
            if status in ("ok", "slow") and self._report_ok(tier, payload):
                rep = payload
                out[tier.name] = rep
                tier.decoded += rep.decoded
                tier.accepted += rep.accepted
                tier.proposed += rep.proposed
                if status == "ok":
                    # decoded counts *emissions* (for spec tiers: accepted
                    # tokens, never rounds or proposals), so the tracker's
                    # tok/s is the acceptance-scaled effective speed
                    if rep.decoded and rep.warm:
                        self.tracker.record(tier.name, rep.decoded, rep.dt)
                    self._observe_success(tier)
                else:
                    # the quantum landed (tokens are in the streams) but
                    # blew the deadline: keep the work, never the sample
                    self._observe_failure(tier, "step deadline exceeded")
            elif status in ("ok", "slow"):
                self._observe_failure(tier, "corrupt StepReport "
                                            f"({payload!r:.80})")
            elif status == "error":
                self._observe_failure(tier, f"step raised: {payload!r:.120}")
            else:                              # "timeout": thread still runs
                self._observe_failure(
                    tier, "step deadline exceeded (still running)")
            if tier.inflight is not None:
                continue                       # engine owned by its thread
            leftovers = tier.engine.take_pending()
            if leftovers:
                for req in leftovers:       # back to global, reroutable
                    # only un-count requests this router actually placed —
                    # work submitted to the engine directly just joins the
                    # global queue without touching the tier's stats
                    if self.assigned.pop(req.rid, None) is not None:
                        tier.routed -= 1
                self.queue.extend(leftovers)
        self._finish_retries()
        if self.queue:
            self.queue.sort(key=lambda r: order.get(id(r), len(order)))
        self.cycles += 1
        self.cycle_log.append({
            "queued": len(self.queue),
            "routed": routed,
            "decoded": {name: rep.decoded for name, rep in out.items()},
            "health": {t.name: t.health for t in self.tiers},
        })
        return out

    # ---- supervisor internals (§8) ---------------------------------------
    def _deadline(self, tier: EngineTier) -> float | None:
        """Effective per-step deadline: the engine's own hook wins, the
        pool policy is the default."""
        own = getattr(tier.engine, "step_deadline_s", None)
        return own if own is not None else self.policy.step_deadline_s

    def _step_tiers(self, busy: list[EngineTier]) -> list[tuple]:
        """Step every busy tier; returns (status, payload) per tier,
        parallel to ``busy``, with status "ok" (payload StepReport), "slow" (report, but past the
        deadline), "error" (exception), or "timeout" (concurrent only —
        the step future missed its deadline and is still running; the
        tier's ``inflight`` now owns the engine until it completes)."""
        outcomes: list[tuple] = []
        if self._pool is not None and len(busy) > 1:
            t0 = time.perf_counter()
            futs = [(t, self._pool.submit(t.engine.step)) for t in busy]
            for tier, fut in futs:
                dl = self._deadline(tier)
                try:
                    if dl is None:
                        rep = fut.result()
                    else:
                        rep = fut.result(
                            timeout=max(0.0, t0 + dl - time.perf_counter()))
                    el = time.perf_counter() - t0
                    outcomes.append(("slow", rep)
                                    if dl is not None and el > dl
                                    else ("ok", rep))
                except FuturesTimeout:
                    tier.inflight = fut
                    outcomes.append(("timeout", None))
                except Exception as e:
                    outcomes.append(("error", e))
        else:
            for tier in busy:
                dl = self._deadline(tier)
                s0 = time.perf_counter()
                try:
                    rep = tier.engine.step()
                except Exception as e:
                    outcomes.append(("error", e))
                    continue
                el = time.perf_counter() - s0
                # serial steps cannot be preempted; the watchdog is post-hoc
                outcomes.append(("slow", rep)
                                if dl is not None and el > dl
                                else ("ok", rep))
        return outcomes

    def _report_ok(self, tier: EngineTier, rep) -> bool:
        """Reject corrupt step reports (NaN timings, impossible token
        counts) before they reach streams' accounting or the shared
        tracker — a sick device lies; the supervisor must not believe
        it."""
        if not isinstance(rep, StepReport):
            return False
        eng = tier.engine
        cap = eng.max_slots * max(1, getattr(eng, "quantum_tokens",
                                             eng.decode_quantum))
        return (math.isfinite(rep.dt) and rep.dt >= 0
                and 0 <= rep.decoded <= cap
                and 0 <= rep.admitted <= eng.max_slots
                and 0 <= rep.accepted <= max(rep.proposed, 0))

    def _set_health(self, tier: EngineTier, state: str, reason: str) -> None:
        if state == tier.health:
            return
        self.health_log.append({"cycle": self.cycles, "tier": tier.name,
                                "from": tier.health, "to": state,
                                "reason": reason})
        tier.health = state

    def _observe_success(self, tier: EngineTier) -> None:
        tier.fail_streak = 0
        if tier.health == DEGRADED:
            self._set_health(tier, HEALTHY, "clean step")
        elif tier.health == PROBATION:
            tier.probation_ok += 1
            if tier.probation_ok >= self.policy.probation_steps:
                tier.quarantine_len = self.policy.quarantine_cycles
                self._set_health(tier, HEALTHY,
                                 f"{tier.probation_ok} clean canary steps")

    def _observe_failure(self, tier: EngineTier, reason: str) -> None:
        tier.fail_streak += 1
        tier.failures += 1
        if tier.health == PROBATION:
            # the canary failed: straight back, exponentially longer hold
            self._quarantine(tier, f"canary failed: {reason}", doubled=True)
        elif tier.fail_streak >= self.policy.quarantine_after:
            self._quarantine(tier, reason)
        else:
            self._set_health(tier, DEGRADED, reason)

    def _quarantine(self, tier: EngineTier, reason: str, *,
                    doubled: bool = False) -> None:
        if doubled:
            tier.quarantine_len = min(max(tier.quarantine_len, 1) * 2, 64)
        else:
            tier.quarantine_len = self.policy.quarantine_cycles
        tier.quarantined_at = self.cycles
        tier.probation_ok = 0
        self._set_health(tier, QUARANTINED, reason)
        if tier.inflight is None:
            self._reclaim_tier(tier)
        else:
            tier.reclaimed = False     # deferred until the thread lets go

    def _reclaim_tier(self, tier: EngineTier) -> None:
        """Pull every request off a quarantined tier — un-admitted pending
        and admitted in-flight alike — releasing its pages
        (`Engine.abort`). Both go through the retry law: a pending request
        has no tokens to resume (it re-queues verbatim) but its attempt
        still counts, otherwise a request repeatedly routed to a tier
        that dies with it pending would bounce forever instead of
        converging to a dead letter. Admission leftovers reclaimed from
        *healthy* tiers (in ``step``) stay penalty-free — refusal is
        backpressure, not failure."""
        tier.reclaimed = True
        try:
            reqs = tier.engine.take_pending() + tier.engine.abort()
        except Exception:              # engine too broken even to reclaim;
            return                     # its requests will hit the stall law
        for req in reqs:
            if self.assigned.pop(req.rid, None) is not None:
                tier.routed -= 1
        tier.reclaims += len(reqs)
        self._retry(reqs, tier)

    def _retry(self, reqs: list[Request], tier: EngineTier) -> None:
        """Request-level retry (§8): each failed request re-enters the
        queue after exponential backoff, re-prefilled from its original
        prompt plus already-emitted tokens (`plan_resume`) so greedy
        recovery is token-identical; past the budget it is dead-lettered."""
        eos = self.tiers[0].engine.eos_id
        for req in reqs:
            ent = self._resume.get(req.rid)
            if ent is None:
                ent = {"req": req, "prompt": list(req.prompt),
                       "max_new": req.max_new, "prefix": [], "attempts": 0}
                self._resume[req.rid] = ent
            ent["attempts"] += 1
            if ent["attempts"] > self.policy.retry_budget:
                self._dead_letter(
                    req, f"retry budget of {self.policy.retry_budget} "
                         f"exhausted (last failure on tier {tier.name})")
                continue
            plan = plan_resume(req.prompt, req.out, req.max_new, eos)
            if plan is None:
                self._finish_resume(req, mark_done=True)   # already terminal
                continue
            prompt, remaining = plan
            if all(len(prompt) >= t.engine.max_len for t in self.tiers):
                # context-capped: the unfailed stream would have ended here
                self._finish_resume(req, mark_done=True)
                continue
            ent["prefix"].extend(req.out)
            req.prompt, req.max_new, req.out = prompt, remaining, []
            req.done = False
            delay = self.policy.retry_backoff * (1 << (ent["attempts"] - 1))
            self._delayed.append((self.cycles + delay, req))
            self.retries += 1

    def _dead_letter(self, req: Request, msg: str) -> None:
        """Terminal failure: restore the request's original identity and
        partial stream, record the typed error, stop tracking it.
        ``req.done`` stays False — the stream did NOT complete."""
        ent = self._resume.pop(req.rid, None)
        if ent is not None:
            req.prompt = ent["prompt"]
            req.max_new = ent["max_new"]
            req.out = ent["prefix"] + req.out
        self.dead_letters[req.rid] = RequestFailedError(
            f"request {req.rid}: {msg}")

    def _finish_resume(self, req: Request, *, mark_done: bool) -> None:
        """A retried stream terminated: stitch the emitted prefix back and
        restore the caller-visible prompt/budget."""
        ent = self._resume.pop(req.rid, None)
        if ent is not None:
            req.prompt = ent["prompt"]
            req.max_new = ent["max_new"]
            req.out = ent["prefix"] + req.out
        if mark_done:
            req.done = True

    def _finish_retries(self) -> None:
        for rid in [rid for rid, ent in self._resume.items()
                    if ent["req"].done]:
            self._finish_resume(self._resume[rid]["req"], mark_done=False)

    def _release_delayed(self) -> None:
        if not self._delayed:
            return
        ready = [r for c, r in self._delayed if c <= self.cycles]
        self._delayed = [(c, r) for c, r in self._delayed if c > self.cycles]
        self.queue.extend(ready)

    def _poll_inflight(self) -> None:
        """Collect step futures that earlier blew their deadline. Their
        report is discarded (whatever tokens the hung quantum emitted are
        already in the request streams and covered by the resume law);
        a tier quarantined while its thread still ran is reclaimed now."""
        for tier in self.tiers:
            fut = tier.inflight
            if fut is None or not fut.done():
                continue
            tier.inflight = None
            try:
                fut.result()
            except Exception:
                pass
            if tier.health == QUARANTINED and not tier.reclaimed:
                self._reclaim_tier(tier)

    def _advance_health(self) -> None:
        for tier in self.tiers:
            if (tier.health == QUARANTINED and tier.reclaimed
                    and tier.inflight is None
                    and self.cycles - tier.quarantined_at
                    >= tier.quarantine_len):
                tier.fail_streak = 0
                tier.probation_ok = 0
                self._set_health(tier, PROBATION,
                                 f"quarantine of {tier.quarantine_len} "
                                 f"cycles served")

    # ---- drive to completion ---------------------------------------------
    def _guard_limit(self) -> int:
        """Aggregate of the per-engine guard: every request needs ≲ one
        admission cycle plus max_new/quantum decode cycles; 8× slack."""
        quantum = min((t.engine.decode_quantum if t.engine.fast else 1)
                      for t in self.tiers)
        reqs = list(self.queue) + [r for _, r in self._delayed]
        for t in self.tiers:
            reqs += t.engine.pending
            reqs += [r for r in t.engine.slot_req if r is not None]
        tokens = sum(max(1, r.max_new) for r in reqs)
        # §8 slack: every retry replays admission + decode, and failed
        # requests idle through quarantine holds and exponential backoff
        p = self.policy
        recovery = 8 * (p.retry_budget + 1) * (
            p.quarantine_cycles + (p.retry_backoff << p.retry_budget))
        return 64 + recovery + 8 * (len(reqs) + -(-tokens // quantum))

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve ``requests`` to completion across the pool. Raises
        :class:`EngineStallError` with per-tier diagnostics if the pool
        stops making progress (scheduling bug or global starvation) —
        but only *after* failure hygiene (§8): every tier's slots and
        pages are reclaimed and every unfinished request is dead-lettered
        with a :class:`~repro.serve.engine.RequestFailedError` recording
        the stall, so the caller sees per-request terminal states and the
        pool is clean for fresh submissions, not half-drained.

        Requests that were dead-lettered *during* a successful run (retry
        budget exhausted) do not raise — check ``dead_letters`` /
        ``Request.done``."""
        for r in requests:
            self.submit(r)
        guard, limit = 0, self._guard_limit()
        while self.has_work():
            if guard >= limit:
                diag = (
                    f"multi-engine made no progress after {guard} cycles "
                    f"(limit {limit}): {len(self.queue)} queued, "
                    f"{len(self._delayed)} backing off; "
                    + "; ".join(self._tier_diag(t) for t in self.tiers))
                self._fail_outstanding(f"pool stalled — {diag}")
                raise EngineStallError(diag)
            self.step()
            guard += 1
        return requests

    def _fail_outstanding(self, reason: str) -> None:
        """Stall hygiene: reclaim every tier (slots emptied, pages
        released — the allocator invariant holds afterwards) and
        dead-letter every unfinished request with its partial stream
        restored. A tier whose hung step thread still owns its engine is
        skipped — touching it would race the thread; its requests are
        dead-lettered from the bookkeeping side only."""
        orphans: list[Request] = []
        for t in self.tiers:
            if t.inflight is not None:
                continue
            try:
                orphans += t.engine.take_pending()
                orphans += t.engine.abort()
            except Exception:
                pass
        orphans += self.queue + [r for _, r in self._delayed]
        self.queue, self._delayed = [], []
        for req in orphans:
            if not req.done:
                self._dead_letter(req, reason)

    def drain(self) -> None:
        """Finish all admitted and queued work without new submissions."""
        self.run([])

    def _tier_diag(self, tier: EngineTier) -> str:
        eng = tier.engine
        busy = sum(1 for r in eng.slot_req if r is not None)
        d = (f"{tier.name}: {tier.health}, {len(eng.pending)} pending, "
             f"{busy}/{eng.max_slots} slots busy, "
             f"{tier.failures} failures")
        if tier.inflight is not None:
            d += ", step thread hung"
        if eng.paged:
            d += f", {len(eng.alloc.free)} pages free"
        return d

    # ---- reporting -------------------------------------------------------
    def stats(self) -> dict:
        """Aggregated completion/throughput report across tiers."""
        snap = self.tracker.snapshot()
        tiers = {}
        for t in self.tiers:
            s = snap[t.name]
            tiers[t.name] = {
                "kind": t.kind,
                "routed": t.routed,
                "decoded": t.decoded,
                "accepted": t.accepted,
                "proposed": t.proposed,
                "acceptance": (t.accepted / t.proposed if t.proposed else 0.0),
                "tok_s": s.ewma_thr,
                "busy_time": s.busy_time,
                "unit_cost": t.unit_cost,
                "health": t.health,
                "failures": t.failures,
                "reclaims": t.reclaims,
            }
        return {"cycles": self.cycles, "queued": len(self.queue),
                "retries": self.retries,
                "dead_letters": {rid: str(e)
                                 for rid, e in self.dead_letters.items()},
                "tiers": tiers}


def make_multi_engine(cfg: ModelConfig, ctx: ShardCtx,
                      tier_kws: list[dict], *, seed: int = 0,
                      concurrent: bool = True,
                      policy: HealthPolicy | None = None,
                      **shared_kw) -> MultiEngine:
    """Build a tier pool over ONE shared parameter set.

    Each dict in ``tier_kws`` holds that tier's Engine kwargs plus the
    optional routing keys ``name`` / ``kind`` / ``unit_cost`` /
    ``prior_tok_s``; ``shared_kw`` is merged under every tier (tier keys
    win). Sharing the materialized parameters is what makes the tiers
    token-equivalent at ``temperature=0`` — and costs one copy of the
    model, not N.

        meng = make_multi_engine(cfg, ctx, [
            {"name": "dense"},
            {"name": "paged", "paged": True, "page_size": 8},
        ], max_slots=4, max_len=128)

    A big/little speculative tier rides the same mechanism — pass that
    tier ``draft_cfg``/``draft_params``/``spec_k`` in its dict; at
    ``temperature=0`` its stream is token-identical to the plain tiers'
    (greedy spec-decode equivalence, DESIGN.md §7), so pool outputs stay
    tier-independent.
    """
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    tiers = []
    for i, kw in enumerate(tier_kws):
        kw = {**shared_kw, **kw}
        name = kw.pop("name", f"tier{i}")
        kind = kw.pop("kind", "core")
        unit_cost = kw.pop("unit_cost", 1.0)
        prior = kw.pop("prior_tok_s", 1.0)
        tiers.append(EngineTier(name, Engine(cfg, params, ctx, **kw),
                                kind=kind, unit_cost=unit_cost,
                                prior_tok_s=prior))
    return MultiEngine(tiers, concurrent=concurrent, policy=policy)
