"""Request-granularity routing across heterogeneous engine tiers.

The paper's dynamic scheduler (§3) splits an iteration space across a pool
of CPU cores and an FPGA in proportion to each resource's *measured*
throughput. At serving granularity the iteration space is the queue of
pending requests, measured in **token units** (prompt tokens + decode
budget), and the resources are `Engine` tiers (device classes, cache
layouts, or model sizes). This module is the pure, jax-free routing law
consumed by :class:`repro.serve.multi_engine.MultiEngine`:

* :func:`request_units` — the work measure of one request;
* :func:`route_requests` — one routing round: split the queued units over
  the tiers with :func:`repro.core.chunking.proportional_split` (per-tier
  measured tok/s over token-unit cost), respecting per-tier admission
  capacity and per-request tier eligibility;
* :func:`apply_health` — the quarantine/probation capacity mask of the
  tier health supervisor (DESIGN.md §8): a quarantined tier takes
  nothing, a probation tier takes at most one canary request.

Work conservation: a tier with no capacity this round (slots full, pool
exhausted, stalled) simply takes nothing — its proportional share spills to
the live tiers instead of queueing behind the dead one. Requests beyond the
aggregate capacity stay queued (global admission backpressure).

Speculative tiers need no special casing here: an engine decoding with a
draft model reports *emitted* tokens per quantum (accepted draft tokens
plus the verify correction — DESIGN.md §7), so the measured tok/s this
module divides by unit cost is already the acceptance-scaled **effective**
speed. Acceptance collapsing on some workload shows up as a falling
measured speed, and the proportional law sheds load from that tier with
no extra signal.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.chunking import proportional_split


# Tier health states (DESIGN.md §8). Pure strings so the routing law stays
# jax-free and the state machine is trivially serializable/loggable.
HEALTHY = "healthy"          # full proportional share
DEGRADED = "degraded"        # recent failure(s), still below the
#                              quarantine threshold — routes normally
QUARANTINED = "quarantined"  # masked out entirely; in-flight reclaimed
PROBATION = "probation"      # re-admitted with a single canary request
HEALTH_STATES = (HEALTHY, DEGRADED, QUARANTINED, PROBATION)


def apply_health(capacities: Sequence[int], states: Sequence[str],
                 busy: Sequence[int], *, canary: int = 1) -> list[int]:
    """Mask per-tier routing capacity by tier health.

    The quarantine/probation law expressed on capacities, which is how
    :func:`route_requests` already encodes dead tiers (capacity 0 takes
    nothing and its proportional share spills to the live tiers — same
    work-conservation path as a stalled or pool-exhausted tier):

    * ``quarantined`` — capacity 0: the tier is ineligible for every
      request this cycle, full stop.
    * ``probation`` — at most ``canary`` requests in flight across slots
      and pending (``busy[i]``): the tier must prove itself on a single
      canary before its full share is restored; a second request is not
      risked on a tier that just came out of quarantine.
    * ``healthy`` / ``degraded`` — untouched. Degraded is a bookkeeping
      state (failures seen, threshold not reached); starving it would turn
      one transient fault into a self-fulfilling outage.

    Pure host code, unit-testable without engines.
    """
    if not len(capacities) == len(states) == len(busy):
        raise ValueError(f"{len(capacities)} capacities, {len(states)} "
                         f"states, {len(busy)} busy counts")
    out = []
    for c, s, b in zip(capacities, states, busy):
        if s not in HEALTH_STATES:
            raise ValueError(f"unknown health state {s!r} "
                             f"(expected one of {HEALTH_STATES})")
        if s == QUARANTINED:
            out.append(0)
        elif s == PROBATION:
            out.append(min(int(c), max(0, canary - int(b))))
        else:
            out.append(int(c))
    return out


def request_units(prompt_len: int, max_new: int) -> int:
    """Token units of one request: prompt tokens to prefill plus the decode
    budget. This is the unit `proportional_split` divides across tiers, and
    the same unit the single-engine HBB admission law budgets in."""
    return max(1, prompt_len) + max(0, max_new)


def tier_speeds(throughputs: Sequence[float], priors: Sequence[float],
                unit_costs: Sequence[float]) -> list[float]:
    """Effective routing speed per tier: measured tok/s (falling back to the
    tier's prior until the tracker has a sample) divided by the tier's
    token-unit cost. A tier twice as expensive per token (energy, $/hour,
    contention) is routed half the work its raw throughput would earn."""
    out = []
    for thr, prior, cost in zip(throughputs, priors, unit_costs):
        eff = thr if thr > 0 else max(prior, 1e-9)
        out.append(eff / max(cost, 1e-9))
    return out


def route_requests(units: Sequence[int], speeds: Sequence[float],
                   capacities: Sequence[int],
                   eligible: Optional[Sequence[Sequence[bool]]] = None,
                   ) -> list[list[int]]:
    """One routing round: assign queued requests to tiers.

    Args:
      units: token units per queued request, FIFO order
        (:func:`request_units`).
      speeds: effective speed per tier (:func:`tier_speeds`).
      capacities: how many requests each tier can accept right now
        (free decode slots; 0 for a stalled or saturated tier).
      eligible: optional per-request tier masks — ``eligible[j][i]`` is
        False when request ``j`` can never run on tier ``i`` (e.g. its
        prompt exceeds that tier's ``max_len``). Default: everywhere.

    Returns:
      Per-tier lists of queue indices, in queue order. The concatenation is
      a subset of ``range(len(units))``; whatever is missing stays queued.

    The split targets `proportional_split(total_units, speeds)` over the
    *live* tiers (capacity > 0): each request goes to the eligible live
    tier with the largest remaining target, so cumulative shares converge
    to the proportional law while FIFO order is preserved per tier. Dead
    tiers take nothing and their share spills to the rest — queued work is
    never blocked behind a stalled tier.

    Assignment considers the most-constrained requests first (fewest
    eligible live tiers; FIFO among equals): a request that can only run
    on one tier — e.g. a long prompt that only the long-context tier can
    hold — claims that tier's capacity before universally-eligible
    requests spill onto it, so scarce tiers serve the work only they can.
    """
    n = len(speeds)
    if len(capacities) != n:
        raise ValueError(f"{len(capacities)} capacities for {n} tiers")
    assign: list[list[int]] = [[] for _ in range(n)]
    if not units:
        return assign
    cap = [int(c) for c in capacities]
    live = [i for i in range(n) if cap[i] > 0]
    if not live:
        return assign
    spd = [max(float(s), 1e-9) for s in speeds]
    total = int(sum(units))
    share = proportional_split(total, [spd[i] for i in live])
    deficit = dict(zip(live, share))

    def n_eligible(j: int) -> int:
        if eligible is None:
            return len(live)
        return sum(1 for i in live if eligible[j][i])

    order = sorted(range(len(units)), key=lambda j: (n_eligible(j), j))
    for j in order:
        u = units[j]
        best = None
        for i in live:
            if cap[i] <= 0:
                continue
            if eligible is not None and not eligible[j][i]:
                continue
            if best is None or deficit[i] > deficit[best]:
                best = i
        if best is None:
            # every eligible tier is full; other requests may still fit a
            # different tier, so keep scanning instead of breaking
            continue
        assign[best].append(j)
        deficit[best] -= u
        cap[best] -= 1
    for lst in assign:
        lst.sort()                 # FIFO order within each tier
    return assign
