"""Single-token decode with flash-decoding (sequence-parallel KV attention).

The KV cache's sequence dim is sharded over ``model``; each shard computes
attention partials (o, m, l) over its slice and the exact softmax is
reconstructed with a max/psum tree — the TPU analogue of flash-decoding.
Cache writes are *local masked* updates inside the same shard_map (the
writing shard is the one whose slice contains `pos`) — no cross-shard
scatter appears in the HLO. Per-sequence positions (B,) support continuous
batching; sliding-window layers use ring addressing (pos mod window).

MLA decodes in the compressed latent space via the absorbed-weights trick:
the cache row *is* both key and value (MQA-style, dim kv_lora+rope).

Paged decode has two implementations selected by ``paged_kernel``:
  * the default Pallas kernel path (`kernels/paged_attention`) — the page
    table is scalar-prefetched and indexed *in-kernel*, one page block per
    grid step, online-softmax carries in VMEM; the gathered `(B, T·ps, …)`
    view never exists in HBM and the engine passes only the *live* prefix
    of the table (bucketed), so decode cost scales with context, not with
    the table width `max_len/page_size`;
  * ``paged_kernel=False`` — the jnp gathered-view implementation
    (`kernels/paged_attention/ref.py`, the PR 2 path) at full table
    width, kept as the escape hatch and the equivalence oracle.
In both, the in-page write of the new token's K/V stays a separate masked
scatter (`_paged_write`) *outside* the attention kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import ops as paged_ops
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import apply_rope, mlp, rmsnorm, rope_tables, _softcap
from repro.models.transformer import layer_schedule
from repro.sharding.axes import ShardCtx

F32 = jnp.float32
NEG = -1e30


# ------------------------------------------------------------ flash decode
def _combine(o, m, l):
    """Cross-shard exact-softmax combine of (o, m, l) partials."""
    m_g = jax.lax.pmax(m, "model")
    m_safe = jnp.where(m_g <= NEG / 2, 0.0, m_g)
    c = jnp.exp(jnp.where(m <= NEG / 2, NEG, m) - m_safe)
    o = jax.lax.psum(o * c[..., None], "model")
    l = jax.lax.psum(l * c, "model")
    return o / jnp.maximum(l, 1e-30)[..., None]


def _local_write(cache, new_row, rel):
    """cache (B, S_loc, …), new_row (B, …), rel (B,) local index (may be out
    of this shard's range → masked no-op)."""
    B, S_loc = cache.shape[0], cache.shape[1]
    in_range = (rel >= 0) & (rel < S_loc)
    relc = jnp.clip(rel, 0, S_loc - 1)
    b = jnp.arange(B)
    cur = cache[b, relc]                                   # (B, …)
    mask = in_range.reshape((B,) + (1,) * (cache.ndim - 2))
    upd = jnp.where(mask, new_row, cur)
    return cache.at[b, relc].set(upd)


def _paged_write(pool, new_row, pt, pos, i, msize):
    """Masked write of `new_row` (B,…) at logical position `pos` (B,) through
    page table `pt` (B,T) into `pool` (N, ps_loc, …). Shard `i` owns in-page
    offsets [i·ps_loc, (i+1)·ps_loc); out-of-range rows are a no-op. Distinct
    live slots hold disjoint pages (allocator invariant), so batch scatters
    never collide except on the reserved trash page 0."""
    B = new_row.shape[0]
    N, ps_loc = pool.shape[0], pool.shape[1]
    T = pt.shape[1]
    ps = ps_loc * msize
    idx = jnp.minimum(pos // ps, T - 1)
    page = jnp.take_along_axis(pt, idx[:, None], axis=1)[:, 0]
    # a slot frozen at pos == max_len (prompt_len = max_len-1 case) still
    # scribbles each step; route it to the trash page, never a live one
    page = jnp.where(pos < T * ps, page, 0)
    if msize == 1:          # every offset is in range on a 1-shard model axis
        return pool.at[page, pos % ps].set(new_row)
    rel = pos % ps - i * ps_loc
    in_range = (rel >= 0) & (rel < ps_loc)
    relc = jnp.clip(rel, 0, ps_loc - 1)
    pagec = jnp.clip(page, 0, N - 1)
    cur = pool[pagec, relc]                                # (B, …)
    mask = in_range.reshape((B,) + (1,) * (pool.ndim - 2))
    return pool.at[pagec, relc].set(jnp.where(mask, new_row, cur))


def _paged_impl(paged_kernel) -> str:
    """Map the user-facing ``paged_kernel`` flag onto a
    `kernels/paged_attention/ops.py` impl name: True → backend auto
    (compiled kernel on TPU, jnp ref elsewhere), a string → forwarded
    verbatim, False → the jnp gathered-view oracle ("ref") — the PR 2
    escape hatch, now one shared implementation instead of an inline
    copy."""
    if isinstance(paged_kernel, (bool, int)):    # 0/1 behave as the bools
        return "" if paged_kernel else "ref"
    return paged_kernel


def _check_paged_args(page_table, pos, *, update: bool = True,
                      window: int = 0) -> None:
    """Typed validation shared by both paged decode entry points — these are
    user-reachable through Engine/flash_decode callers, so they raise
    ValueError instead of tripping asserts (PR 2 convention)."""
    if not update:
        raise ValueError(
            "paged decode always writes the new token's K/V; attend-only "
            "(update=False) callers must use the dense cache path")
    if window:
        raise ValueError(
            f"paged cache is full-attention only (window={window}); "
            "sliding-window layers keep their dense ring buffers")
    if page_table.ndim != 2:
        raise ValueError(
            f"page_table must be (batch, table_width) int32, got shape "
            f"{page_table.shape}")
    if page_table.shape[0] != pos.shape[0]:
        raise ValueError(
            f"page_table batch {page_table.shape[0]} != pos batch "
            f"{pos.shape[0]}")


def flash_decode_gqa(q, k_new, v_new, ck, cv, pos, *, window: int,
                     scale: float, softcap: float, ctx: ShardCtx,
                     update: bool = True, page_table=None,
                     paged_kernel=True):
    """q (B,Hkv,G,dh); k_new/v_new (B,Hkv,dh); ck/cv (B,Sc,Hkv,dh) kv_seq-
    sharded; pos (B,). → (out (B,Hkv,G,dh), ck', cv').

    update=False → attend-only (whisper cross-attention; pos = valid_len-1).
    page_table (B,T) int32 → paged mode: ck/cv are shared page pools
    (num_pages, page_size, Hkv, dh) with the in-page offset kv_seq-sharded
    (full attention only — rings stay dense). ``paged_kernel`` selects the
    Pallas in-kernel table walk (True, or an impl string forwarded to
    `kernels/paged_attention/ops.py`) vs. the jnp gathered-view escape
    hatch (False). Either way the per-shard (o, m, l) partials meet the
    same exact-softmax `_combine` across the model axis.
    """
    mesh = ctx.mesh
    bp = ctx.spec(("batch", None, None, None), q.shape)[0]
    qspec = P(bp, None, None, None)
    nspec = P(bp, None, None)
    pspec = P(bp)

    msize = ctx.axis_size("model")         # static (jax<0.5: no lax.axis_size)

    if page_table is not None:
        _check_paged_args(page_table, pos, update=update, window=window)
        poolspec = ctx.spec((None, "kv_seq", "kv_heads", None), ck.shape)
        ptspec = P(bp, None)
        # paged_kernel=False → pin the jnp gathered-view oracle (ref.py):
        # full-width table, PR 2 cost model, one shared implementation
        impl = _paged_impl(paged_kernel)

        def local_paged(q, kn, vn, pk, pv, pos, pt):
            i = jax.lax.axis_index("model")
            pk = _paged_write(pk, kn, pt, pos, i, msize)
            pv = _paged_write(pv, vn, pt, pos, i, msize)
            B, hkv, grp, dh = q.shape
            o, m, l = paged_ops.paged_attend_gqa(
                q, pk, pv, pt, pos, i, msize, scale=scale,
                softcap=softcap, impl=impl)
            o = o.reshape(B, hkv, grp, dh)
            m = m.reshape(B, hkv, grp)
            l = l.reshape(B, hkv, grp)
            return _combine(o, m, l).astype(q.dtype), pk, pv

        fn = shard_map(local_paged, mesh=mesh,
                       in_specs=(qspec, nspec, nspec, poolspec, poolspec,
                                 pspec, ptspec),
                       out_specs=(qspec, poolspec, poolspec), check_rep=False)
        return fn(q, k_new, v_new, ck, cv, pos, page_table)

    cspec = ctx.spec(("batch", "kv_seq", "kv_heads", None), ck.shape)

    def local(q, kn, vn, ck, cv, pos):
        i = jax.lax.axis_index("model")
        B, S_loc = ck.shape[0], ck.shape[1]
        S_tot = S_loc * msize
        if update:
            wpos = pos % S_tot if window else pos       # ring for windows
            rel = wpos - i * S_loc
            ck = _local_write(ck, kn, rel)
            cv = _local_write(cv, vn, rel)
        gpos = i * S_loc + jnp.arange(S_loc)            # (S_loc,) slot ids
        if window:
            # slot j holds absolute position p_j = pos - ((pos - j) mod S_tot)
            p_j = pos[:, None] - ((pos[:, None] - gpos[None]) % S_tot)
            valid = (p_j >= 0) & (p_j > pos[:, None] - window)
        else:
            valid = gpos[None] <= pos[:, None]          # (B, S_loc)
        s = jnp.einsum("bhgd,bshd->bhgs", q.astype(F32) * scale,
                       ck.astype(F32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid[:, None, None], s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[:, None, None], p, 0.0)
        o = jnp.einsum("bhgs,bshd->bhgd", p, cv.astype(F32))
        l = jnp.sum(p, -1)
        return _combine(o, m, l).astype(q.dtype), ck, cv

    fn = shard_map(local, mesh=mesh,
                   in_specs=(qspec, nspec, nspec, cspec, cspec, pspec),
                   out_specs=(qspec, cspec, cspec), check_rep=False)
    return fn(q, k_new, v_new, ck, cv, pos)


def flash_decode_mla(q_eff, new_row, ckv, pos, *, kv_lora: int, scale: float,
                     ctx: ShardCtx, page_table=None, paged_kernel=True):
    """q_eff (B,H,R); new_row (B,R); ckv (B,Sc,R). Key = cache row, value =
    first kv_lora dims of the same row. page_table → ckv is the shared pool
    (num_pages, page_size, R); `paged_kernel` as in flash_decode_gqa (MLA
    shares the full-attention-only constraint — typed check, not assert)."""
    mesh = ctx.mesh
    bp = ctx.spec(("batch", None, None), q_eff.shape)[0]
    qspec = P(bp, None, None)
    nspec = P(bp, None)
    pspec = P(bp)
    msize = ctx.axis_size("model")

    if page_table is not None:
        _check_paged_args(page_table, pos)
        poolspec = ctx.spec((None, "kv_seq", None), ckv.shape)
        ptspec = P(bp, None)
        impl = _paged_impl(paged_kernel)

        def local_paged(q, row, pool, pos, pt):
            i = jax.lax.axis_index("model")
            pool = _paged_write(pool, row, pt, pos, i, msize)
            o, m, l = paged_ops.paged_attend_mla(
                q, pool, pt, pos, i, msize, kv_lora=kv_lora,
                scale=scale, impl=impl)
            return _combine(o, m, l).astype(q.dtype), pool

        fn = shard_map(local_paged, mesh=mesh,
                       in_specs=(qspec, nspec, poolspec, pspec, ptspec),
                       out_specs=(qspec, poolspec), check_rep=False)
        return fn(q_eff, new_row, ckv, pos, page_table)

    cspec = ctx.spec(("batch", "kv_seq", None), ckv.shape)

    def local(q, row, ckv, pos):
        i = jax.lax.axis_index("model")
        B, S_loc, R = ckv.shape
        rel = pos - i * S_loc
        ckv = _write3(ckv, row, rel)
        gpos = i * S_loc + jnp.arange(S_loc)
        valid = gpos[None] <= pos[:, None]
        s = jnp.einsum("bhr,bsr->bhs", q.astype(F32) * scale,
                       ckv.astype(F32))
        s = jnp.where(valid[:, None], s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[:, None], p, 0.0)
        o = jnp.einsum("bhs,bsr->bhr", p, ckv[..., :kv_lora].astype(F32))
        l = jnp.sum(p, -1)
        return _combine(o, m, l).astype(q.dtype), ckv

    _write3 = _local_write

    fn = shard_map(local, mesh=mesh,
                   in_specs=(qspec, nspec, cspec, pspec),
                   out_specs=(qspec, cspec), check_rep=False)
    return fn(q_eff, new_row, ckv, pos)


# --------------------------------------------------------- per-block decode
def gqa_decode(cfg: ModelConfig, p, x, cache, pos, window, ctx: ShardCtx,
               page_table=None, paged_kernel=True):
    """x (B,D) → (out (B,D), new cache)."""
    B = x.shape[0]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if cfg.use_rope:
        cos, sin = rope_tables(pos, cfg.head_dim, cfg.rope_theta)  # (B, dh/2)
        q = apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
        k = apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
    out, ck, cv = flash_decode_gqa(
        qg, k, v, cache["k"], cache["v"], pos, window=window,
        scale=cfg.head_dim ** -0.5, softcap=cfg.attn_softcap, ctx=ctx,
        page_table=page_table, paged_kernel=paged_kernel)
    out = out.reshape(B, cfg.n_heads * cfg.head_dim)
    o = jnp.einsum("bk,kd->bd",
                   out, p["wo"].reshape(-1, cfg.d_model))
    return ctx.constrain(o, ("batch", None)), {"k": ck, "v": cv}


def mla_decode(cfg: ModelConfig, p, x, cache, pos, ctx: ShardCtx,
               page_table=None, paged_kernel=True):
    m = cfg.mla
    B = x.shape[0]
    x3 = x[:, None, :]
    # queries
    cq = rmsnorm(jnp.einsum("bd,dr->br", x, p["wdq"]), p["q_norm"],
                 cfg.norm_eps)
    q = jnp.einsum("br,rhk->bhk", cq, p["wuq"])
    qn, qr = q[..., :m.nope_dim], q[..., m.nope_dim:]
    cos, sin = rope_tables(pos, m.rope_dim, cfg.rope_theta)
    qr = apply_rope(qr[:, None], cos[:, None], sin[:, None])[:, 0]
    # absorbed query: q_c = qn · W_uk  → latent space
    wuk = p["wukv"][..., :m.nope_dim]                  # (R, H, nope)
    q_c = jnp.einsum("bhn,rhn->bhr", qn, wuk)          # (B, H, kv_lora)
    q_eff = jnp.concatenate([q_c, qr], axis=-1)
    # new cache row
    ckv_t = rmsnorm(jnp.einsum("bd,dr->br", x, p["wdkv"]), p["kv_norm"],
                    cfg.norm_eps)
    kr_t = jnp.einsum("bd,dr->br", x, p["wkr"])
    kr_t = apply_rope(kr_t[:, None, None], cos[:, None], sin[:, None])[:, 0, 0]
    row = jnp.concatenate([ckv_t, kr_t], axis=-1).astype(cache["ckv"].dtype)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    o_c, ckv = flash_decode_mla(q_eff, row, cache["ckv"], pos,
                                kv_lora=m.kv_lora, scale=scale, ctx=ctx,
                                page_table=page_table,
                                paged_kernel=paged_kernel)
    # un-absorb values: o = (o_c · W_uv) then output proj
    wuv = p["wukv"][..., m.nope_dim:]                  # (R, H, v)
    o = jnp.einsum("bhr,rhv->bhv", o_c, wuv)
    o = jnp.einsum("bhv,hvd->bd", o, p["wo"])
    return ctx.constrain(o, ("batch", None)), {"ckv": ckv}


def block_decode(cfg: ModelConfig, bc, p, cache, h, pos, ctx: ShardCtx,
                 page_table=None, paged_kernel=True):
    x = rmsnorm(h, p["norm1"], cfg.norm_eps)
    if bc.mixer == "attn":
        # only full-attention layers are paged; rings keep dense buffers
        pt = None if bc.window else page_table
        if cfg.mla:
            y, new_cache = mla_decode(cfg, p["attn"], x, cache, pos, ctx,
                                      page_table=pt,
                                      paged_kernel=paged_kernel)
        else:
            y, new_cache = gqa_decode(cfg, p["attn"], x, cache, pos,
                                      bc.window, ctx, page_table=pt,
                                      paged_kernel=paged_kernel)
    else:
        step = (mamba_mod.mamba2_step if cfg.ssm.version == 2
                else mamba_mod.mamba1_step)
        y, new_cache = step(cfg, p["mamba"], x, cache, ctx)
    if cfg.use_post_norm:
        y = rmsnorm(y, p["post1"], cfg.norm_eps)
    h = h + y
    if bc.ffn != "none":
        x = rmsnorm(h, p["norm2"], cfg.norm_eps)
        if bc.ffn == "moe":
            y = moe_mod.moe_decode(cfg, p["moe"], x, ctx)
        else:
            y = mlp(cfg, p["mlp"], x[:, None], ctx)[:, 0]
        if cfg.use_post_norm:
            y = rmsnorm(y, p["post2"], cfg.norm_eps)
        h = h + y
    return h, new_cache


# ------------------------------------------------------------- decode step
def decode_step(cfg: ModelConfig, params, cache, tokens, pos, ctx: ShardCtx,
                page_table=None, paged_kernel=True):
    """tokens (B,), pos (B,) → (logits (B,V) f32 vocab-sharded, new cache).
    page_table (B,T) → full-attention cache leaves are page pools."""
    segments = layer_schedule(cfg)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.pdtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    h = ctx.constrain(h, ("batch", None))
    new_blocks = []
    for seg, sp, sc in zip(segments, params["blocks"], cache["blocks"]):

        def body(hc, xs, seg=seg):
            slot_params, slot_cache = xs
            new_slot = {}
            for j, bc in enumerate(seg.pattern):
                hc, nc = block_decode(cfg, bc, slot_params[f"s{j}"],
                                      slot_cache[f"s{j}"], hc, pos, ctx,
                                      page_table=page_table,
                                      paged_kernel=paged_kernel)
                new_slot[f"s{j}"] = nc
            return hc, new_slot

        h, new_sc = jax.lax.scan(body, h, (sp, sc))
        new_blocks.append(new_sc)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["unembed"]["w"])
    logits = jnp.einsum("bd,dv->bv", h, w.astype(h.dtype),
                        preferred_element_type=F32)
    logits = _softcap(logits, cfg.final_softcap)
    logits = ctx.constrain(logits, ("batch", "vocab"))
    return logits, {"blocks": new_blocks}


# ------------------------------------------------------ fused decode loop
def _filter_logits(logits, *, temperature: float, top_k: int,
                   top_p: float = 0.0):
    """Temperature / top-k / nucleus (top-p) filtering → f32 logits ready
    for `jax.random.categorical` (truncated entries at NEG). All three
    knobs are *static* Python floats/ints: `temperature` must be > 0 here
    (greedy never builds a distribution), and top_p in {0, 1.0} — nucleus
    off — adds no HLO at all, so a top_p=1.0 sampler traces to the exact
    same jaxpr as the pre-nucleus sampler."""
    lg = logits.astype(F32) / temperature
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]        # (…, 1)
        lg = jnp.where(lg < kth, NEG, lg)
    if top_p and top_p < 1.0:
        probs = jax.nn.softmax(lg, axis=-1)
        srt = jnp.sort(probs, axis=-1)[..., ::-1]          # descending
        csum = jnp.cumsum(srt, axis=-1)
        # smallest prefix whose mass reaches top_p; (csum - srt) is the mass
        # *before* each entry, so the count is always ≥ 1 (never empty)
        n_keep = jnp.sum((csum - srt < top_p).astype(jnp.int32),
                         axis=-1, keepdims=True)
        thr = jnp.take_along_axis(srt, n_keep - 1, axis=-1)
        lg = jnp.where(probs < thr, NEG, lg)
    return lg


def _sample_tokens(logits, key, *, temperature: float, top_k: int,
                   top_p: float = 0.0):
    """Next-token choice on device. `temperature` is a *static* float:
    0 → greedy argmax (no PRNG consumed, HLO identical to the PR 1 loop);
    > 0 → temperature-scaled (optionally top-k / top-p truncated)
    categorical."""
    if not temperature:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    lg = _filter_logits(logits, temperature=temperature, top_k=top_k,
                        top_p=top_p)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def decode_loop(cfg: ModelConfig, params, cache, tokens, pos, active,
                remaining, ctx: ShardCtx, *, num_steps: int, eos_id: int,
                max_len: int, page_table=None, paged_kernel=True,
                temperature: float = 0.0, top_k: int = 0,
                top_p: float = 0.0, rng=None):
    """Multi-token decode fused into one device program.

    Wraps `decode_step` in a `jax.lax.scan` over a quantum of `num_steps`
    tokens with sampling *on device* and per-slot done masking, so the host
    syncs once per quantum instead of once per token (DESIGN.md §"Serving
    fast path"). Sampling is greedy argmax at `temperature=0` and
    temperature/top-k categorical otherwise; the PRNG key rides in the scan
    carry (split once per step), so real sampling costs zero extra host
    syncs. All carries are (B,)-or-key device arrays the engine keeps
    resident between cycles; the engine jits this with the cache and state
    donated so decoding stops allocating a fresh cache every token.

    Masking: a slot emits while `active`; it deactivates when its token
    budget (`remaining`) drains, it samples `eos_id`, or its write position
    reaches `max_len - 1`. Inactive slots still run (batched decode is a
    fixed quantum) but their emissions are masked and their state frozen;
    whatever they scribble into their cache rows is overwritten by the next
    prefill insert into that slot.

    Returns ((cache, tokens, pos, active, remaining, rng),
             emitted (num_steps, B) int32, emitted_mask (num_steps, B) bool).
    """
    if rng is None:
        rng = jax.random.PRNGKey(0)

    def body(carry, _):
        cache, tokens, pos, active, remaining, key = carry
        logits, cache = decode_step(cfg, params, cache, tokens, pos, ctx,
                                    page_table=page_table,
                                    paged_kernel=paged_kernel)
        if temperature:
            key, sub = jax.random.split(key)
        else:
            sub = key
        nxt = _sample_tokens(logits, sub, temperature=temperature,
                             top_k=top_k, top_p=top_p)
        emit_tok = jnp.where(active, nxt, -1)
        remaining = remaining - active.astype(remaining.dtype)
        pos = pos + active.astype(pos.dtype)
        still = active & (remaining > 0) & (nxt != eos_id) & \
            (pos < max_len - 1)
        tokens = jnp.where(still, nxt, tokens)
        return (cache, tokens, pos, still, remaining, key), (emit_tok, active)

    carry = (cache, tokens, pos, active, remaining, rng)
    carry, (toks, msks) = jax.lax.scan(body, carry, None, length=num_steps)
    return carry, toks, msks


# ------------------------------------------------- speculative decode (§7)
def _merge_partials(o1, m1, l1, o2, m2, l2):
    """Online-softmax merge of two shard-local (o, m, l) partial triples.
    Both inputs are *unnormalized* (o = Σ e^{s-m}·v, l = Σ e^{s-m});
    `_combine` still runs once across the model axis afterwards."""
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(m <= NEG / 2, 0.0, m)
    c1 = jnp.exp(jnp.where(m1 <= NEG / 2, NEG, m1) - m_safe)
    c2 = jnp.exp(jnp.where(m2 <= NEG / 2, NEG, m2) - m_safe)
    o = o1 * c1[..., None] + o2 * c2[..., None]
    l = l1 * c1 + l2 * c2
    return o, m, l


def flash_verify_gqa(q, k_new, v_new, ck, cv, pos0, *, window: int,
                     scale: float, softcap: float, ctx: ShardCtx,
                     page_table=None, paged_kernel=True):
    """Batched K-token verify attention for speculative decode.

    q (B,K,Hkv,G,dh); k_new/v_new (B,K,Hkv,dh) the *staged* K/V rows for
    positions pos0..pos0+K-1; ck/cv the cache exactly as the last commit
    left it; pos0 (B,) the write position of verify input 0. → out
    (B,K,Hkv,G,dh). The cache is READ-ONLY here — query j (absolute
    position pos0+j) attends committed history (< pos0) plus staged rows
    j' ≤ j (self included), which reproduces the serial loop's
    write-then-attend semantics without mutating rows a rejected proposal
    would corrupt; `commit_rows` writes the accepted prefix afterwards.
    Staged scores are contributed by shard 0 only (every shard holds the
    full staged rows — adding them everywhere would double-count in the
    psum). Sliding-window layers require K ≤ window so every staged row
    stays inside every query's window; ring slots are anchored at the last
    committed position pos0-1."""
    mesh = ctx.mesh
    K = q.shape[1]
    if window and K > window:
        raise ValueError(f"verify block K={K} exceeds window={window}")
    bp = ctx.spec(("batch", None, None, None, None), q.shape)[0]
    qspec = P(bp, None, None, None, None)
    nspec = P(bp, None, None, None)
    pspec = P(bp)
    msize = ctx.axis_size("model")
    causal = jnp.arange(K)[:, None] >= jnp.arange(K)[None, :]   # (Kq, Kk)

    def _staged_partials(qf, kn, vn, i):
        # qf f32·scale (B,K,Hkv,G,dh); kn/vn (B,K,Hkv,dh)
        s = jnp.einsum("bkhgd,bjhd->bhgkj", qf, kn.astype(F32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        keep = jnp.logical_and(i == 0, causal)[None, None, None]
        s = jnp.where(keep, s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(keep, p, 0.0)
        o = jnp.einsum("bhgkj,bjhd->bhgkd", p, vn.astype(F32))
        return o, m, jnp.sum(p, -1)

    if page_table is not None:
        _check_paged_args(page_table, pos0, window=window)
        poolspec = ctx.spec((None, "kv_seq", "kv_heads", None), ck.shape)
        ptspec = P(bp, None)
        impl = _paged_impl(paged_kernel)

        def local_paged(q, kn, vn, pk, pv, pos0, pt):
            i = jax.lax.axis_index("model")
            B, K, hkv, grp, dh = q.shape
            qf = q.reshape(B * K, hkv, grp, dh)
            # committed history only: kernel validity is gpos ≤ pos, so
            # pass pos0-1 for every query (there is always a prefilled
            # prompt, so pos0 ≥ 1 whenever the slot's output is consumed)
            posf = jnp.repeat(pos0 - 1, K, axis=0)
            ptf = jnp.repeat(pt, K, axis=0)
            o, m, l = paged_ops.paged_attend_gqa(
                qf, pk, pv, ptf, posf, i, msize, scale=scale,
                softcap=softcap, impl=impl)
            o = jnp.moveaxis(o.reshape(B, K, hkv, grp, dh), 1, 3)
            m = jnp.moveaxis(m.reshape(B, K, hkv, grp), 1, 3)
            l = jnp.moveaxis(l.reshape(B, K, hkv, grp), 1, 3)
            o2, m2, l2 = _staged_partials(q.astype(F32) * scale, kn, vn, i)
            out = _combine(*_merge_partials(o, m, l, o2, m2, l2))
            return jnp.moveaxis(out, 3, 1).astype(q.dtype)

        fn = shard_map(local_paged, mesh=mesh,
                       in_specs=(qspec, nspec, nspec, poolspec, poolspec,
                                 pspec, ptspec),
                       out_specs=qspec, check_rep=False)
        return fn(q, k_new, v_new, ck, cv, pos0, page_table)

    cspec = ctx.spec(("batch", "kv_seq", "kv_heads", None), ck.shape)

    def local(q, kn, vn, ck, cv, pos0):
        i = jax.lax.axis_index("model")
        B, S_loc = ck.shape[0], ck.shape[1]
        S_tot = S_loc * msize
        gpos = i * S_loc + jnp.arange(S_loc)
        qpos = pos0[:, None] + jnp.arange(K)[None]              # (B, K)
        if window:
            # ring content is anchored at the last *committed* position:
            # slot j holds p_j = (pos0-1) - ((pos0-1 - j) mod S_tot); the
            # staged rows cover pos0..pos0+K-1 and K ≤ window keeps them
            # all in-window for every query
            anchor = pos0[:, None] - 1
            p_j = anchor - ((anchor - gpos[None]) % S_tot)       # (B, S_loc)
            valid = (p_j >= 0)[:, None, :] & \
                (p_j[:, None, :] > qpos[:, :, None] - window)    # (B,K,S_loc)
        else:
            valid = jnp.broadcast_to(
                (gpos[None] < pos0[:, None])[:, None, :], (B, K, S_loc))
        qf = q.astype(F32) * scale
        s = jnp.einsum("bkhgd,bshd->bhgks", qf, ck.astype(F32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(valid[:, None, None], s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[:, None, None], p, 0.0)
        o = jnp.einsum("bhgks,bshd->bhgkd", p, cv.astype(F32))
        l = jnp.sum(p, -1)
        o2, m2, l2 = _staged_partials(qf, kn, vn, i)
        out = _combine(*_merge_partials(o, m, l, o2, m2, l2))
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(qspec, nspec, nspec, cspec, cspec, pspec),
                   out_specs=qspec, check_rep=False)
    return fn(q, k_new, v_new, ck, cv, pos0)


def flash_verify_mla(q_eff, new_rows, ckv, pos0, *, kv_lora: int,
                     scale: float, ctx: ShardCtx, page_table=None,
                     paged_kernel=True):
    """MLA analogue of `flash_verify_gqa`: q_eff (B,K,H,R); new_rows
    (B,K,R) the staged latent rows; ckv (B,Sc,R) or the (N,ps,R) pool. →
    out (B,K,H,kv_lora). Read-only; full-attention only (typed check)."""
    mesh = ctx.mesh
    K = q_eff.shape[1]
    bp = ctx.spec(("batch", None, None, None), q_eff.shape)[0]
    qspec = P(bp, None, None, None)
    nspec = P(bp, None, None)
    pspec = P(bp)
    msize = ctx.axis_size("model")
    causal = jnp.arange(K)[:, None] >= jnp.arange(K)[None, :]

    def _staged_partials(qf, rows, i):
        # qf f32·scale (B,K,H,R); rows (B,K,R)
        s = jnp.einsum("bkhr,bjr->bhkj", qf, rows.astype(F32))
        keep = jnp.logical_and(i == 0, causal)[None, None]
        s = jnp.where(keep, s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(keep, p, 0.0)
        o = jnp.einsum("bhkj,bjr->bhkr", p, rows[..., :kv_lora].astype(F32))
        return o, m, jnp.sum(p, -1)

    if page_table is not None:
        _check_paged_args(page_table, pos0)
        poolspec = ctx.spec((None, "kv_seq", None), ckv.shape)
        ptspec = P(bp, None)
        impl = _paged_impl(paged_kernel)

        def local_paged(q, rows, pool, pos0, pt):
            i = jax.lax.axis_index("model")
            B, K, H, R = q.shape
            qf = q.reshape(B * K, H, R)
            posf = jnp.repeat(pos0 - 1, K, axis=0)
            ptf = jnp.repeat(pt, K, axis=0)
            o, m, l = paged_ops.paged_attend_mla(
                qf, pool, ptf, posf, i, msize, kv_lora=kv_lora,
                scale=scale, impl=impl)
            o = jnp.moveaxis(o.reshape(B, K, H, kv_lora), 1, 2)
            m = jnp.moveaxis(m.reshape(B, K, H), 1, 2)
            l = jnp.moveaxis(l.reshape(B, K, H), 1, 2)
            o2, m2, l2 = _staged_partials(q.astype(F32) * scale, rows, i)
            out = _combine(*_merge_partials(o, m, l, o2, m2, l2))
            return jnp.moveaxis(out, 2, 1).astype(q.dtype)

        fn = shard_map(local_paged, mesh=mesh,
                       in_specs=(qspec, nspec, poolspec, pspec, ptspec),
                       out_specs=qspec, check_rep=False)
        return fn(q_eff, new_rows, ckv, pos0, page_table)

    cspec = ctx.spec(("batch", "kv_seq", None), ckv.shape)

    def local(q, rows, ckv, pos0):
        i = jax.lax.axis_index("model")
        S_loc = ckv.shape[1]
        gpos = i * S_loc + jnp.arange(S_loc)
        valid = gpos[None] < pos0[:, None]                      # (B, S_loc)
        qf = q.astype(F32) * scale
        s = jnp.einsum("bkhr,bsr->bhks", qf, ckv.astype(F32))
        s = jnp.where(valid[:, None, None], s, NEG)
        m = jnp.max(s, -1)
        m_safe = jnp.where(m <= NEG / 2, 0.0, m)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[:, None, None], p, 0.0)
        o = jnp.einsum("bhks,bsr->bhkr", p, ckv[..., :kv_lora].astype(F32))
        l = jnp.sum(p, -1)
        o2, m2, l2 = _staged_partials(qf, rows, i)
        out = _combine(*_merge_partials(o, m, l, o2, m2, l2))
        return jnp.moveaxis(out, 2, 1).astype(q.dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(qspec, nspec, cspec, pspec),
                   out_specs=qspec, check_rep=False)
    return fn(q_eff, new_rows, ckv, pos0)


def gqa_verify(cfg: ModelConfig, p, x, cache, pos0, window, ctx: ShardCtx,
               page_table=None, paged_kernel=True):
    """x (B,K,D) → (out (B,K,D), staged {"k","v"} rows (B,K,Hkv,dh))."""
    B, K = x.shape[:2]
    q = jnp.einsum("bkd,dhe->bkhe", x, p["wq"])
    k = jnp.einsum("bkd,dhe->bkhe", x, p["wk"])
    v = jnp.einsum("bkd,dhe->bkhe", x, p["wv"])
    if cfg.use_rope:
        qpos = pos0[:, None] + jnp.arange(K)[None]
        cos, sin = rope_tables(qpos, cfg.head_dim, cfg.rope_theta)  # (B,K,·)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, K, cfg.n_kv_heads, G, cfg.head_dim)
    out = flash_verify_gqa(qg, k, v, cache["k"], cache["v"], pos0,
                           window=window, scale=cfg.head_dim ** -0.5,
                           softcap=cfg.attn_softcap, ctx=ctx,
                           page_table=page_table, paged_kernel=paged_kernel)
    out = out.reshape(B, K, cfg.n_heads * cfg.head_dim)
    o = jnp.einsum("bke,ed->bkd", out, p["wo"].reshape(-1, cfg.d_model))
    staged = {"k": k.astype(cache["k"].dtype),
              "v": v.astype(cache["v"].dtype)}
    return ctx.constrain(o, ("batch", None, None)), staged


def mla_verify(cfg: ModelConfig, p, x, cache, pos0, ctx: ShardCtx,
               page_table=None, paged_kernel=True):
    """x (B,K,D) → (out (B,K,D), staged {"ckv"} latent rows (B,K,R))."""
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bkd,dr->bkr", x, p["wdq"]), p["q_norm"],
                 cfg.norm_eps)
    q = jnp.einsum("bkr,rhe->bkhe", cq, p["wuq"])
    qn, qr = q[..., :m.nope_dim], q[..., m.nope_dim:]
    qpos = pos0[:, None] + jnp.arange(x.shape[1])[None]
    cos, sin = rope_tables(qpos, m.rope_dim, cfg.rope_theta)   # (B,K,·)
    qr = apply_rope(qr, cos, sin)
    wuk = p["wukv"][..., :m.nope_dim]                  # (R, H, nope)
    q_c = jnp.einsum("bkhn,rhn->bkhr", qn, wuk)
    q_eff = jnp.concatenate([q_c, qr], axis=-1)
    ckv_t = rmsnorm(jnp.einsum("bkd,dr->bkr", x, p["wdkv"]), p["kv_norm"],
                    cfg.norm_eps)
    kr_t = jnp.einsum("bkd,dr->bkr", x, p["wkr"])
    kr_t = apply_rope(kr_t[:, :, None], cos, sin)[:, :, 0]
    rows = jnp.concatenate([ckv_t, kr_t], axis=-1).astype(cache["ckv"].dtype)
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    o_c = flash_verify_mla(q_eff, rows, cache["ckv"], pos0,
                           kv_lora=m.kv_lora, scale=scale, ctx=ctx,
                           page_table=page_table, paged_kernel=paged_kernel)
    wuv = p["wukv"][..., m.nope_dim:]                  # (R, H, v)
    o = jnp.einsum("bkhr,rhv->bkhv", o_c, wuv)
    o = jnp.einsum("bkhv,hvd->bkd", o, p["wo"])
    return ctx.constrain(o, ("batch", None, None)), {"ckv": rows}


def block_verify(cfg: ModelConfig, bc, p, cache, h, pos0, ctx: ShardCtx,
                 page_table=None, paged_kernel=True):
    """h (B,K,D) → (h', staged). Attention layers stage their K new
    K/V rows; mamba layers scan the single-token step over the K inputs
    and stage the K intermediate states (SSMs are inherently serial —
    verify only batches the attention/FFN work)."""
    x = rmsnorm(h, p["norm1"], cfg.norm_eps)
    if bc.mixer == "attn":
        pt = None if bc.window else page_table
        if cfg.mla:
            y, staged = mla_verify(cfg, p["attn"], x, cache, pos0, ctx,
                                   page_table=pt, paged_kernel=paged_kernel)
        else:
            y, staged = gqa_verify(cfg, p["attn"], x, cache, pos0,
                                   bc.window, ctx, page_table=pt,
                                   paged_kernel=paged_kernel)
    else:
        step = (mamba_mod.mamba2_step if cfg.ssm.version == 2
                else mamba_mod.mamba1_step)

        def sbody(state, xt):
            yt, nstate = step(cfg, p["mamba"], xt, state, ctx)
            return nstate, (yt, nstate)

        _, (ys, states) = jax.lax.scan(sbody, cache, jnp.moveaxis(x, 1, 0))
        y = jnp.moveaxis(ys, 0, 1)
        staged = states                                # leaves (K, B, …)
    if cfg.use_post_norm:
        y = rmsnorm(y, p["post1"], cfg.norm_eps)
    h = h + y
    if bc.ffn != "none":
        x = rmsnorm(h, p["norm2"], cfg.norm_eps)
        if bc.ffn == "moe":
            B, K, D = x.shape
            y = moe_mod.moe_decode(cfg, p["moe"], x.reshape(B * K, D),
                                   ctx).reshape(B, K, D)
        else:
            y = mlp(cfg, p["mlp"], x, ctx)
        if cfg.use_post_norm:
            y = rmsnorm(y, p["post2"], cfg.norm_eps)
        h = h + y
    return h, staged


def decode_verify(cfg: ModelConfig, params, cache, tokens, pos0,
                  ctx: ShardCtx, page_table=None, paged_kernel=True):
    """Speculative verify pass. tokens (B,K) = [last committed token,
    proposals g_1..g_{K-1}]; pos0 (B,) the write position of tokens[:,0].
    → (logits (B,K,V) f32, staged tree). logits[:, j] is the target's
    next-token distribution after consuming tokens[:, :j+1] — exactly what
    K serial `decode_step`s would produce, in one batched pass. The cache
    is read-only; `decode_commit` writes the accepted prefix."""
    segments = layer_schedule(cfg)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.pdtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    h = ctx.constrain(h, ("batch", None, None))
    staged_blocks = []
    for seg, sp, sc in zip(segments, params["blocks"], cache["blocks"]):

        def body(hc, xs, seg=seg):
            slot_params, slot_cache = xs
            stg = {}
            for j, bc in enumerate(seg.pattern):
                hc, s = block_verify(cfg, bc, slot_params[f"s{j}"],
                                     slot_cache[f"s{j}"], hc, pos0, ctx,
                                     page_table=page_table,
                                     paged_kernel=paged_kernel)
                stg[f"s{j}"] = s
            return hc, stg

        h, stg = jax.lax.scan(body, h, (sp, sc))
        staged_blocks.append(stg)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["unembed"]["w"])
    logits = jnp.einsum("bkd,dv->bkv", h, w.astype(h.dtype),
                        preferred_element_type=F32)
    logits = _softcap(logits, cfg.final_softcap)
    logits = ctx.constrain(logits, ("batch", None, "vocab"))
    return logits, {"blocks": staged_blocks}


# -------------------------------------------------- multi-token KV commit
def commit_rows(cache, rows, pos0, n, ctx: ShardCtx, *, window: int = 0,
                axes, page_table=None):
    """Write the accepted prefix of staged `rows` (B,K,…) into one
    attention cache leaf: row j lands at absolute position pos0+j for
    j < n (B,). Dense leaves use the same shard-local masked writes as the
    serial loop (ring addressing for windows); paged leaves route each row
    through the page table, with rejected rows (j ≥ n) deflected to the
    trash page 0 exactly like a frozen slot's scribble. `axes` is the
    leaf's logical-axis tuple (the caller knows the layout)."""
    mesh = ctx.mesh
    K = rows.shape[1]
    msize = ctx.axis_size("model")
    bp = ctx.spec(("batch",) + (None,) * (rows.ndim - 1), rows.shape)[0]
    rspec = P(*((bp,) + (None,) * (rows.ndim - 1)))
    pspec = P(bp)

    if page_table is not None:
        _check_paged_args(page_table, pos0, window=window)
        poolspec = ctx.spec(axes, cache.shape)
        ptspec = P(bp, None)

        def local(pool, rows, pt, pos0, n):
            i = jax.lax.axis_index("model")
            T, ps = pt.shape[1], pool.shape[1] * msize
            for j in range(K):
                # rejected rows route to the trash page (pos ≥ T·ps)
                pos = jnp.where(j < n, pos0 + j, T * ps)
                pool = _paged_write(pool, rows[:, j], pt, pos, i, msize)
            return pool

        fn = shard_map(local, mesh=mesh,
                       in_specs=(poolspec, rspec, ptspec, pspec, pspec),
                       out_specs=poolspec, check_rep=False)
        return fn(cache, rows, page_table, pos0, n)

    cspec = ctx.spec(axes, cache.shape)

    def local(cache, rows, pos0, n):
        i = jax.lax.axis_index("model")
        S_loc = cache.shape[1]
        S_tot = S_loc * msize
        for j in range(K):
            pos = pos0 + j
            wpos = pos % S_tot if window else pos
            rel = jnp.where(j < n, wpos - i * S_loc, -1)
            cache = _local_write(cache, rows[:, j], rel)
        return cache

    fn = shard_map(local, mesh=mesh,
                   in_specs=(cspec, rspec, pspec, pspec),
                   out_specs=cspec, check_rep=False)
    return fn(cache, rows, pos0, n)


def _commit_scan_state(cache, states, n):
    """Mamba leaves: `states` (K,B,…) are the K post-step states staged by
    `block_verify`; keep state n-1 per batch row (n = 0 → the pre-verify
    state, i.e. nothing advanced)."""
    def sel(c, s):
        full = jnp.concatenate([c[None], s.astype(c.dtype)], axis=0)
        return full[n, jnp.arange(c.shape[0])]
    return jax.tree.map(sel, cache, states)


def block_commit(cfg: ModelConfig, bc, cache, staged, pos0, n,
                 ctx: ShardCtx, page_table=None):
    if bc.mixer != "attn":
        return _commit_scan_state(cache, staged, n)
    pt = None if bc.window else page_table
    if cfg.mla:
        axes = ((None, "kv_seq", None) if pt is not None
                else ("batch", "kv_seq", None))
        return {"ckv": commit_rows(cache["ckv"], staged["ckv"], pos0, n,
                                   ctx, window=bc.window, axes=axes,
                                   page_table=pt)}
    axes = ((None, "kv_seq", "kv_heads", None) if pt is not None
            else ("batch", "kv_seq", "kv_heads", None))
    return {"k": commit_rows(cache["k"], staged["k"], pos0, n, ctx,
                             window=bc.window, axes=axes, page_table=pt),
            "v": commit_rows(cache["v"], staged["v"], pos0, n, ctx,
                             window=bc.window, axes=axes, page_table=pt)}


def decode_commit(cfg: ModelConfig, cache, staged, pos0, n, ctx: ShardCtx,
                  page_table=None):
    """Commit half of the verify/commit split: write the first n (B,)
    staged rows/states into the cache. Positions pos0..pos0+n-1 receive
    the K/V of the accepted verify *inputs*; the correction token is NOT
    written — it becomes the next round's tokens[:,0] and its row is
    staged (and committed) by the next verify."""
    new_blocks = []
    for seg, sc, st in zip(layer_schedule(cfg), cache["blocks"],
                           staged["blocks"]):

        def body(c, xs, seg=seg):
            slot_cache, slot_staged = xs
            out = {}
            for j, bc in enumerate(seg.pattern):
                out[f"s{j}"] = block_commit(cfg, bc, slot_cache[f"s{j}"],
                                            slot_staged[f"s{j}"], pos0, n,
                                            ctx, page_table=page_table)
            return c, out

        _, new_sc = jax.lax.scan(body, 0, (sc, st))
        new_blocks.append(new_sc)
    return {"blocks": new_blocks}


# --------------------------------------------- acceptance / emission law
def spec_candidates(proposals, corrections, accept, active, remaining,
                    pos0, *, eos_id: int, max_len: int):
    """The pure emission law of one speculative round (unit-testable).

    proposals (B,k): draft tokens g_1..g_k. corrections (B,k+1): the
    target's fallback token at each acceptance depth (argmax in greedy
    mode, residual/bonus sample otherwise; index k is the bonus). accept
    (B,k): per-proposal verifier verdicts. active/remaining/pos0 (B,): the
    slot state entering the round.

    Returns (cand (B,K), emit (B,K) bool, n (B,), m (B,)) with K = k+1:
    m = accepted prefix length = Σ cumprod(accept); cand[j] = g_{j+1} for
    j < m else corrections[m]; emit marks the emitted prefix after EOS /
    token-budget / max_len truncation — exactly the prefix the serial loop
    would have emitted over its next n = emit.sum() steps (the still-active
    law `active & (remaining>0) & (tok≠eos) & (pos<max_len-1)` applied
    cumulatively), which is what makes greedy spec-decode token-identical
    to target-only decoding."""
    B, k = proposals.shape
    K = k + 1
    m = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1), axis=1)
    x = jnp.take_along_axis(corrections, m[:, None], axis=1)[:, 0]
    g_pad = jnp.concatenate(
        [proposals, jnp.zeros((B, 1), proposals.dtype)], axis=1)
    jj = jnp.arange(K)[None]
    cand = jnp.where(jj < m[:, None], g_pad, x[:, None])
    prev = jnp.concatenate(
        [jnp.full((B, 1), -1, cand.dtype), cand[:, :-1]], axis=1)
    cond = (jj <= m[:, None]) & (prev != eos_id) & \
        (remaining[:, None] > jj) & (pos0[:, None] + jj < max_len - 1)
    # the first token is the serial loop's unconditional step: an active
    # slot always emits at least one token per round
    cond = jnp.concatenate([jnp.ones((B, 1), bool), cond[:, 1:]], axis=1)
    emit = active[:, None] & (jnp.cumprod(cond.astype(jnp.int32), 1) > 0)
    n = jnp.sum(emit.astype(jnp.int32), axis=1)
    return cand, emit, n, m


def spec_decode_loop(cfg: ModelConfig, draft_cfg: ModelConfig, params,
                     draft_params, cache, draft_cache, tokens, pos, active,
                     remaining, ctx: ShardCtx, *, spec_k: int,
                     num_steps: int, eos_id: int, max_len: int,
                     page_table=None, paged_kernel=True,
                     temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 0.0, rng=None):
    """Speculative decode quantum: each scan step runs `spec_k` serial
    draft steps plus ONE batched target verify, emitting up to spec_k+1
    tokens per slot per round.

    Greedy (temperature=0): a proposal is accepted iff it equals the
    target argmax at its depth and corrections are target argmaxes, so the
    emitted stream is token-identical to the serial loop. Sampled:
    Leviathan/Chen rejection sampling against the *processed*
    (temperature/top-k/top-p) distributions p and q — accept g with
    probability min(1, p(g)/q(g)), on rejection at depth i resample from
    the residual norm(max(p_i - q_i, 0)), and after k acceptances draw the
    bonus token from p_k — which preserves the target-only sampling law.

    The draft writes its dense cache optimistically at pos..pos+k-1; rows
    beyond the accepted prefix are stale, but the draft is validated to be
    full-attention/dense-only (validity gpos ≤ pos), so a stale row is
    always overwritten by the next round's step at that position before it
    ever becomes attendable. The target cache is never written by verify;
    `decode_commit` writes exactly the accepted prefix.

    Returns ((caches, tokens, pos, active, remaining, rng), toks, msks,
    acc) where caches = {"tgt", "dft"}, toks/msks are (num_steps, K, B) in
    emission order and acc (num_steps, B) counts accepted proposals."""
    K = spec_k + 1
    if rng is None:
        rng = jax.random.PRNGKey(0)

    def body(carry, _):
        tcache, dcache, tokens, pos, active, remaining, key = carry

        def dbody(dc, _):
            dcache, dtok, dpos, dkey = dc
            dlogits, dcache = decode_step(draft_cfg, draft_params, dcache,
                                          dtok, dpos, ctx)
            if temperature:
                dkey, sub = jax.random.split(dkey)
                fl = _filter_logits(dlogits, temperature=temperature,
                                    top_k=top_k, top_p=top_p)
                g = jax.random.categorical(sub, fl, -1).astype(jnp.int32)
                q = jax.nn.softmax(fl, axis=-1)
            else:
                g = jnp.argmax(dlogits, -1).astype(jnp.int32)
                q = jnp.zeros((dlogits.shape[0], 0), F32)      # unused
            return (dcache, g, dpos + 1, dkey), (g, q)

        (dcache, _, _, key), (g, qp) = jax.lax.scan(
            dbody, (dcache, tokens, pos, key), None, length=spec_k)
        gT = jnp.moveaxis(g, 0, 1)                             # (B, k)

        vt = jnp.concatenate([tokens[:, None], gT], axis=1)    # (B, K)
        logits, staged = decode_verify(cfg, params, tcache, vt, pos, ctx,
                                       page_table=page_table,
                                       paged_kernel=paged_kernel)

        if temperature:
            key, k_acc, k_res = jax.random.split(key, 3)
            fl = _filter_logits(logits, temperature=temperature,
                                top_k=top_k, top_p=top_p)
            pp = jax.nn.softmax(fl, axis=-1)                   # (B, K, V)
            qT = jnp.moveaxis(qp, 0, 1)                        # (B, k, V)
            p_at = jnp.take_along_axis(pp[:, :spec_k], gT[..., None],
                                       axis=-1)[..., 0]
            q_at = jnp.take_along_axis(qT, gT[..., None], axis=-1)[..., 0]
            u = jax.random.uniform(k_acc, gT.shape, F32)
            accept = u * q_at < p_at           # u < p/q without the divide
            r = jnp.maximum(pp[:, :spec_k] - qT, 0.0)
            rsum = jnp.sum(r, -1, keepdims=True)
            r = jnp.where(rsum > 0.0, r, pp[:, :spec_k])   # p ≡ q → use p
            resid = jnp.concatenate([r, pp[:, spec_k:]], axis=1)
            corrections = jax.random.categorical(
                k_res, jnp.log(resid + 1e-30), axis=-1).astype(jnp.int32)
        else:
            corrections = jnp.argmax(logits, -1).astype(jnp.int32)
            accept = gT == corrections[:, :spec_k]

        cand, emit, n, m = spec_candidates(gT, corrections, accept, active,
                                           remaining, pos, eos_id=eos_id,
                                           max_len=max_len)
        tcache = decode_commit(cfg, tcache, staged, pos, n, ctx,
                               page_table=page_table)
        emit_tok = jnp.where(emit, cand, -1)
        remaining = remaining - n.astype(remaining.dtype)
        pos = pos + n.astype(pos.dtype)
        last = jnp.take_along_axis(cand, jnp.maximum(n - 1, 0)[:, None],
                                   axis=1)[:, 0]
        still = active & (remaining > 0) & (last != eos_id) & \
            (pos < max_len - 1)
        tokens = jnp.where(still, last, tokens)
        acc = jnp.where(active, m, 0).astype(jnp.int32)
        carry = (tcache, dcache, tokens, pos, still, remaining, key)
        return carry, (emit_tok.T, emit.T, acc)

    carry = (cache, draft_cache, tokens, pos, active, remaining, rng)
    carry, (toks, msks, acc) = jax.lax.scan(body, carry, None,
                                            length=num_steps)
    tcache, dcache, tokens, pos, active, remaining, key = carry
    carry = ({"tgt": tcache, "dft": dcache}, tokens, pos, active,
             remaining, key)
    return carry, toks, msks, acc


def decode_loop_fn(cfg: ModelConfig, ctx: ShardCtx, *, num_steps: int,
                   eos_id: int, max_len: int, paged: bool = False,
                   paged_kernel=True, temperature: float = 0.0,
                   top_k: int = 0, top_p: float = 0.0,
                   draft_cfg: ModelConfig | None = None, spec_k: int = 0):
    """Engine-facing closure, shaped for jit(donate_argnums=(1,…,6)).

    Returns (carry, packed) where `packed` is one (2·num_steps + 1, B) int32
    array — emitted tokens, emission masks, then the post-quantum `active`
    vector — so the engine's quantum costs exactly ONE blocking host fetch
    (three separate fetches would sync the pipe three times). The PRNG key
    is carry slot 5, donated and device-resident like the rest. In paged
    mode the loop takes the (B,T) page table as a trailing, non-donated
    arg; the engine passes only the table's *live* prefix (bucketed), which
    is what lets the kernel path skip dead pages wholesale.

    `draft_cfg` + `spec_k` switch the quantum to the speculative loop:
    `params`/`cache` become {"tgt", "dft"} trees, each round emits up to
    spec_k+1 tokens, and `packed` grows to
    (2·num_steps·(spec_k+1) + num_steps + 1, B) — emitted tokens, emission
    masks (both round-major in emission order), per-round accepted-proposal
    counts, then `active`. Still exactly ONE host fetch per quantum."""

    if draft_cfg is not None:
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1 with a draft, got "
                             f"{spec_k}")
        NK = num_steps * (spec_k + 1)

        def _pack_spec(carry, toks, msks, acc):
            active = carry[3]
            B = active.shape[0]
            return carry, jnp.concatenate(
                [toks.reshape(NK, B), msks.astype(jnp.int32).reshape(NK, B),
                 acc, active[None].astype(jnp.int32)], axis=0)

        if paged:
            def loop(params, cache, tokens, pos, active, remaining, rng,
                     page_table):
                carry, toks, msks, acc = spec_decode_loop(
                    cfg, draft_cfg, params["tgt"], params["dft"],
                    cache["tgt"], cache["dft"], tokens, pos, active,
                    remaining, ctx, spec_k=spec_k, num_steps=num_steps,
                    eos_id=eos_id, max_len=max_len, page_table=page_table,
                    paged_kernel=paged_kernel, temperature=temperature,
                    top_k=top_k, top_p=top_p, rng=rng)
                return _pack_spec(carry, toks, msks, acc)
            return loop

        def loop(params, cache, tokens, pos, active, remaining, rng):
            carry, toks, msks, acc = spec_decode_loop(
                cfg, draft_cfg, params["tgt"], params["dft"],
                cache["tgt"], cache["dft"], tokens, pos, active,
                remaining, ctx, spec_k=spec_k, num_steps=num_steps,
                eos_id=eos_id, max_len=max_len, paged_kernel=paged_kernel,
                temperature=temperature, top_k=top_k, top_p=top_p, rng=rng)
            return _pack_spec(carry, toks, msks, acc)
        return loop

    def _pack(carry, toks, msks):
        active = carry[3]
        return carry, jnp.concatenate(
            [toks, msks.astype(jnp.int32), active[None].astype(jnp.int32)],
            axis=0)

    if paged:
        def loop(params, cache, tokens, pos, active, remaining, rng,
                 page_table):
            carry, toks, msks = decode_loop(
                cfg, params, cache, tokens, pos, active, remaining, ctx,
                num_steps=num_steps, eos_id=eos_id, max_len=max_len,
                page_table=page_table, paged_kernel=paged_kernel,
                temperature=temperature, top_k=top_k, top_p=top_p, rng=rng)
            return _pack(carry, toks, msks)
        return loop

    def loop(params, cache, tokens, pos, active, remaining, rng):
        carry, toks, msks = decode_loop(
            cfg, params, cache, tokens, pos, active, remaining, ctx,
            num_steps=num_steps, eos_id=eos_id, max_len=max_len,
            temperature=temperature, top_k=top_k, top_p=top_p, rng=rng)
        return _pack(carry, toks, msks)

    return loop


# ---------------------------------------------------- whisper decode step
def whisper_decode_step(cfg: ModelConfig, params, cache, tokens, pos,
                        ctx: ShardCtx):
    """Decoder step against per-layer self cache + prefilled cross KV."""
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.pdtype)
    h = h + jnp.take(params["dec_pos"],
                     jnp.clip(pos, 0, cfg.max_decoder_len - 1), axis=0)
    h = ctx.constrain(h, ("batch", None))
    G = cfg.n_heads // cfg.n_kv_heads

    def body(hc, xs):
        p, c = xs
        B = hc.shape[0]
        x = rmsnorm(hc, p["norm1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", x, p["self_attn"]["wq"])
        k = jnp.einsum("bd,dhk->bhk", x, p["self_attn"]["wk"])
        v = jnp.einsum("bd,dhk->bhk", x, p["self_attn"]["wv"])
        qg = q.reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
        o, ck, cv = flash_decode_gqa(qg, k, v, c["k"], c["v"], pos, window=0,
                                     scale=cfg.head_dim ** -0.5, softcap=0.0,
                                     ctx=ctx)
        o = jnp.einsum("bk,kd->bd", o.reshape(B, -1),
                       p["self_attn"]["wo"].reshape(-1, cfg.d_model))
        hc = hc + ctx.constrain(o, ("batch", None))
        # cross attention against the (static) prefilled cross KV
        x = rmsnorm(hc, p["norm_x"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", x, p["cross"]["wq"])
        qg = q.reshape(B, cfg.n_kv_heads, G, cfg.head_dim)
        enc_len = jnp.full((B,), c["xk"].shape[1] - 1, jnp.int32)
        o, _, _ = flash_decode_gqa(qg, jnp.zeros_like(k), jnp.zeros_like(v),
                                   c["xk"], c["xv"],
                                   enc_len, window=0,
                                   scale=cfg.head_dim ** -0.5, softcap=0.0,
                                   ctx=ctx, update=False)
        o = jnp.einsum("bk,kd->bd", o.reshape(B, -1),
                       p["cross"]["wo"].reshape(-1, cfg.d_model))
        hc = hc + ctx.constrain(o, ("batch", None))
        x = rmsnorm(hc, p["norm2"], cfg.norm_eps)
        hc = hc + mlp(cfg, p["mlp"], x[:, None], ctx)[:, 0]
        return hc, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

    h, new_dec = jax.lax.scan(body, h, (params["dec_blocks"],
                                        cache["dec_blocks"]))
    h = rmsnorm(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h,
                        params["embed"]["table"].T.astype(h.dtype),
                        preferred_element_type=F32)
    logits = ctx.constrain(logits, ("batch", "vocab"))
    return logits, {"dec_blocks": new_dec}


def serve_step_fn(cfg: ModelConfig, ctx: ShardCtx):
    fn = whisper_decode_step if cfg.enc_dec else decode_step

    def step(params, cache, tokens, pos):
        return fn(cfg, params, cache, tokens, pos, ctx)

    return step


# --------------------------------------------------- resume-from-emitted
def plan_resume(prompt, out, max_new: int, eos_id: int = -1):
    """Retry law for a stream reclaimed from a failed tier (DESIGN.md §8).

    Returns ``(resume_prompt, remaining_new)`` — the prompt to re-prefill
    and the decode budget left — or ``None`` when the stream is already
    terminal (budget spent, or the last emitted token is EOS) and needs no
    retry.

    Why the recovery is token-identical for greedy traffic: the emitted
    prefix was produced by causal decoding, so the model's distribution
    for token ``len(out)+1`` depends only on ``prompt + out`` — exactly
    the context a fresh prefill of ``resume_prompt`` scores. This is the
    same read-only-cache discipline the speculative verify path relies on
    (§7: verify scores positions against cache + staged rows without
    writing), applied across engines instead of within a quantum: the
    failed tier's cache is *garbage* after a fault, so instead of trusting
    it we rebuild the identical context from the tokens the host already
    holds. At ``temperature=0`` the continuation therefore equals what the
    unfailed stream would have produced byte-for-byte; sampled traffic
    resumes the same law but not the same draws (the PRNG position is not
    part of a request's identity).
    """
    emitted = len(out)
    if emitted >= max_new:
        return None                       # budget already spent
    if eos_id >= 0 and emitted and out[-1] == eos_id:
        return None                       # stream ended at EOS
    return list(prompt) + list(out), max_new - emitted
