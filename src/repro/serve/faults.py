"""Deterministic fault injection for the serving pool (DESIGN.md §8).

A serving pool that claims to survive sick tiers needs every failure path
exercised in tier-1 — which means faults must be *injectable on a
reproducible schedule*, not waited for. :class:`FaultyEngine` wraps an
:class:`~repro.serve.engine.Engine` behind the exact tier-facing surface
``MultiEngine`` drives (``step`` / ``plan_admission`` / ``take_pending`` /
``has_work`` / ``drain`` / ``abort`` / ``submit``) and injects the fault
taxonomy on a seeded schedule:

=============  ==========================================================
kind           what the supervisor sees
=============  ==========================================================
``"raise"``    ``step()`` raises :class:`InjectedFault` *before* touching
               the wrapped engine — the quantum is lost, engine state
               stays coherent (a device reset / kernel abort).
``"hang"``     ``step()`` sleeps ``hang_s`` first, then runs the real
               quantum — wall time blows the tier's step deadline but the
               work lands (a wedged interconnect / preempted VM). Tokens
               emitted during a hung step are kept: the resume law
               continues from them.
``"exhaust"``  ``plan_admission()`` reports 0 capacity for the scheduled
               cycles (transient pool pressure). NOT a failure — the
               router's existing work-conservation reroutes around it and
               tier health must stay ``healthy``.
``"nan"``      ``step()`` skips the quantum and returns a corrupt
               :class:`~repro.serve.engine.StepReport` (NaN ``dt``,
               absurd ``decoded``) — silent device corruption. The
               supervisor must reject the report (never feed it to the
               throughput tracker) and count a failure.
=============  ==========================================================

Schedules are deterministic by construction: explicit step indices
(``at``), a periodic window, or a seeded Bernoulli draw per step — the
draw sequence depends only on ``seed``, so a failing scenario replays
bit-identically from its parameters. Everything here is host-side
bookkeeping; no jax imports.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.serve.engine import Engine, Request, StepReport

FAULT_KINDS = ("raise", "hang", "exhaust", "nan")


class InjectedFault(RuntimeError):
    """The step exception :class:`FaultyEngine` raises on a scheduled
    ``"raise"`` fault. A distinct type so tests can assert the supervisor
    survived *this* injection rather than some incidental error."""


@dataclass(frozen=True)
class Fault:
    """One deterministic fault line of a :class:`FaultyEngine` schedule.

    A fault *triggers* at engine-local step index ``i`` when ``i`` is in
    ``at``, or when ``every > 0`` and ``i % every == phase``, or when the
    seeded Bernoulli draw for step ``i`` is below ``p``. A trigger at
    ``i`` keeps the fault active for steps ``[i, i + n)`` — ``n > 1``
    models a tier that stays sick for several quanta (what drives
    degraded → quarantined: *consecutive* failures).

    Attributes:
      kind: one of :data:`FAULT_KINDS`.
      at: explicit trigger step indices.
      every: periodic trigger period (0: off).
      phase: offset of the periodic trigger.
      p: per-step trigger probability, drawn from ``seed`` (0: off).
      seed: RNG seed for the Bernoulli schedule; same seed → same
        schedule, independent of wall clock or call pattern.
      n: consecutive steps a trigger stays active.
      hang_s: sleep injected per hung step (``kind="hang"`` only).
    """
    kind: str
    at: tuple[int, ...] = ()
    every: int = 0
    phase: int = 0
    p: float = 0.0
    seed: int = 0
    n: int = 1
    hang_s: float = 0.05

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if self.n < 1:
            raise ValueError(f"fault n must be >= 1, got {self.n}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault p must be in [0, 1], got {self.p}")

    def schedule(self, horizon: int) -> list[bool]:
        """Active mask for steps ``[0, horizon)`` — the reproducibility
        contract: a pure function of the Fault's fields."""
        rng = np.random.default_rng(self.seed)
        trig = [False] * horizon
        for i in range(horizon):
            draw = rng.random()            # always advance: index-stable
            if (i in self.at
                    or (self.every > 0 and i % self.every == self.phase)
                    or (self.p > 0 and draw < self.p)):
                trig[i] = True
        active = [False] * horizon
        for i, t in enumerate(trig):
            if t:
                for j in range(i, min(i + self.n, horizon)):
                    active[j] = True
        return active


class FaultyEngine:
    """An :class:`~repro.serve.engine.Engine` that fails on schedule.

    Presents the same tier-facing surface as the engine it wraps, so a
    ``MultiEngine`` tier (or a bare caller) cannot tell it apart until a
    fault fires. ``step``-shaped faults key off the wrapper's own step
    counter; ``exhaust`` keys off the *admission-probe* counter
    (``plan_admission`` calls), since that is the call the router gates
    capacity on. All other attribute access passes through, so routing
    diagnostics, page allocators and guard limits see the real engine.

    ``fault_log`` records ``(counter, kind)`` per injection for tests and
    the bench to assert the schedule fired as planned.
    """

    def __init__(self, engine: Engine, faults: list[Fault], *,
                 horizon: int = 4096):
        for f in faults:
            if not isinstance(f, Fault):
                raise ValueError(f"faults must be Fault instances, "
                                 f"got {type(f).__name__}")
        self.engine = engine
        self.faults = list(faults)
        self.horizon = horizon
        self._active = [(f, f.schedule(horizon)) for f in faults]
        self.steps_seen = 0
        self.probes_seen = 0
        self.fault_log: list[tuple[int, str]] = []

    def _firing(self, kind: str, idx: int) -> Fault | None:
        for f, mask in self._active:
            if f.kind == kind and idx < self.horizon and mask[idx]:
                return f
        return None

    # ---- tier-facing surface (same contract as Engine) -------------------
    def step(self) -> StepReport:
        """One engine cycle, possibly sabotaged: ``raise`` loses the
        quantum, ``hang`` delays it past any deadline, ``nan`` replaces
        its report with garbage. The wrapped engine's own state is only
        ever advanced by *real* steps, so recovery tests measure the
        supervisor, not wrapper corruption."""
        idx = self.steps_seen
        self.steps_seen += 1
        if self._firing("raise", idx):
            self.fault_log.append((idx, "raise"))
            raise InjectedFault(f"injected step failure at step {idx}")
        if self._firing("nan", idx):
            self.fault_log.append((idx, "nan"))
            # quantum discarded: a corrupt report means the device's output
            # cannot be trusted, so nothing must reach request streams
            return StepReport(admitted=0, decoded=1 << 30, dt=float("nan"),
                              warm=True)
        f = self._firing("hang", idx)
        if f is not None:
            self.fault_log.append((idx, "hang"))
            time.sleep(f.hang_s)
        return self.engine.step()

    def plan_admission(self, reqs: list[Request]) -> int:
        """Admission probe; an active ``exhaust`` fault reports zero
        capacity (transient pool pressure) without touching health."""
        idx = self.probes_seen
        self.probes_seen += 1
        if self._firing("exhaust", idx):
            self.fault_log.append((idx, "exhaust"))
            return 0
        return self.engine.plan_admission(reqs)

    def submit(self, req: Request) -> None:
        self.engine.submit(req)

    def take_pending(self) -> list[Request]:
        return self.engine.take_pending()

    def has_work(self) -> bool:
        return self.engine.has_work()

    def drain(self) -> None:
        # loop the wrapper's own step so scheduled faults fire during a
        # drain too (Engine.drain would call the real step and bypass them)
        while self.has_work():
            self.step()

    def abort(self) -> list[Request]:
        return self.engine.abort()

    def __getattr__(self, name):
        # everything not faulted (free_slots, pending, slot_req, max_len,
        # alloc, paged, fast, decode_quantum, …) is the real engine's
        return getattr(self.engine, name)
