"""Prefill: full forward pass that also *builds* the decode cache.

Reuses the training-stack projections (gqa_project / mla_latents / mamba
mixers with return_state) so prefill and decode are numerically consistent
with training — tested by decode-vs-full-forward equivalence tests.

Cache layout matches serve.kv_cache exactly (kv_seq sharded over ``model``;
ring layout for windowed layers: position p lands in slot p mod window).

Bucketed serving fast path (DESIGN.md §"Serving fast path"): prompts are
right-padded to a power-of-2 length bucket and prefilled *batched* with an
explicit per-row ``prompt_len``. Causality guarantees real rows never attend
pad keys; the last-token logits are gathered at ``prompt_len - 1`` per row,
and ring caches are packed by a position-mod-window gather that skips pad
positions entirely. One XLA compile per bucket instead of one per distinct
prompt length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import _softcap, embed, mlp, rmsnorm
from repro.models.transformer import BlockCfg, layer_schedule
from repro.models import whisper as whisper_mod
from repro.serve.kv_cache import attn_cache_len
from repro.sharding.axes import ShardCtx

F32 = jnp.float32


def _pad_to(k: jax.Array, Sc: int):
    S = k.shape[1]
    if S >= Sc:
        return k[:, :Sc]
    pad = [(0, 0), (0, Sc - S)] + [(0, 0)] * (k.ndim - 2)
    return jnp.pad(k, pad)


def _ring_pack(k: jax.Array, Sc: int):
    """(B,S,…) → last-window entries laid out so pos p is at slot p mod Sc."""
    S = k.shape[1]
    if S <= Sc:
        return _pad_to(k, Sc)
    tail = k[:, S - Sc:]                       # positions S-Sc … S-1
    shift = (S - Sc) % Sc
    return jnp.roll(tail, shift, axis=1)


def bucket_len(n: int, *, min_bucket: int = 16,
               max_bucket: int | None = None) -> int:
    """Smallest power-of-2 length bucket holding an n-token prompt.

    Bounded below by `min_bucket` (tiny prompts share one compile) and above
    by `max_bucket` (the engine's max_len); n must fit the cap.
    """
    b = max(min_bucket, 1 << (max(int(n), 1) - 1).bit_length())
    if max_bucket is not None:
        b = min(b, max_bucket)
    if b < n:   # typed, not assert: Engine.submit surfaces this upstream
        raise ValueError(
            f"prompt of {n} tokens exceeds the {max_bucket}-token cap")
    return b


def _ring_pack_pl(k: jax.Array, Sc: int, prompt_len: jax.Array):
    """Per-row ring pack: (B,S,…) + prompt_len (B,) → (B,Sc,…) where ring
    slot j holds the *last* real position p ≤ prompt_len-1 with p ≡ j
    (mod Sc). Pad positions (≥ prompt_len) never enter the ring — a plain
    tail-roll would let them displace real tokens whenever the padded
    bucket length exceeds the window."""
    S = k.shape[1]
    j = jnp.arange(Sc)
    last = prompt_len[:, None] - 1                          # (B, 1)
    p_j = last - ((last - j[None, :]) % Sc)                 # (B, Sc)
    valid = p_j >= 0                                        # slot occupied?
    idx = jnp.clip(p_j, 0, S - 1).reshape(p_j.shape + (1,) * (k.ndim - 2))
    g = jnp.take_along_axis(k, idx, axis=1)
    mask = valid.reshape(valid.shape + (1,) * (k.ndim - 2))
    return jnp.where(mask, g, jnp.zeros((), k.dtype))


def gqa_prefill(cfg: ModelConfig, p, x, ctx: ShardCtx, *, window, positions,
                seq_len_cache: int, prompt_len=None):
    """Attention + cache build. x (B,S,D) → (out, {"k","v"}).

    `prompt_len` (B,) marks right-padded rows (bucketed fast path): the
    causal mask already keeps real rows from attending pad keys, but ring
    caches must pack per-row so pad positions can't wrap onto real ones.
    """
    B, S = x.shape[:2]
    if attn_mod._cp_eligible(cfg, ctx):
        o, k, v = attn_mod.cp_gqa_attention(cfg, p, x, ctx, window=window,
                                            causal=True, return_kv=True)
    else:
        q, k, v = attn_mod.gqa_project(cfg, p, x, ctx, positions)
        scale = cfg.head_dim ** -0.5
        out = attn_mod.attend_chunked(q, k, v, scale=scale, causal=True,
                                      window=window,
                                      softcap=cfg.attn_softcap,
                                      q_chunk=cfg.attn_chunk,
                                      kv_chunk=cfg.attn_chunk)
        out = out.reshape(B, S, cfg.n_heads, cfg.head_dim)
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        o = ctx.constrain(o, ("batch", "seq", None))
    if window and prompt_len is not None:
        ck = _ring_pack_pl(k, seq_len_cache, prompt_len)
        cv = _ring_pack_pl(v, seq_len_cache, prompt_len)
    elif window:
        ck = _ring_pack(k, seq_len_cache)
        cv = _ring_pack(v, seq_len_cache)
    else:
        # non-ring: pad rows land at positions ≥ prompt_len, which decode
        # never attends before overwriting — no per-row repack needed
        ck, cv = _pad_to(k, seq_len_cache), _pad_to(v, seq_len_cache)
    ck = ctx.constrain(ck, ("batch", "kv_seq", "kv_heads", None))
    cv = ctx.constrain(cv, ("batch", "kv_seq", "kv_heads", None))
    return o, {"k": ck, "v": cv}


def mla_prefill(cfg: ModelConfig, p, x, ctx: ShardCtx, *, positions,
                seq_len_cache: int | None = None):
    m = cfg.mla
    B, S, _ = x.shape
    qn, qr = attn_mod.mla_queries(cfg, p, x, ctx, positions)
    c_kv, k_r = attn_mod.mla_latents(cfg, p, x, ctx, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wukv"])
    kv = ctx.constrain(kv, ("batch", None, "heads", None))
    kn, v = kv[..., :m.nope_dim], kv[..., m.nope_dim:]
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(k_r, (B, S, cfg.n_heads, m.rope_dim)
                              ).astype(kn.dtype)], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)[:, :, :, None, :]
    scale = (m.nope_dim + m.rope_dim) ** -0.5
    out = attn_mod.attend_chunked(q, k, v, scale=scale, causal=True,
                                  q_chunk=cfg.attn_chunk,
                                  kv_chunk=cfg.attn_chunk)
    out = out.reshape(B, S, cfg.n_heads, m.v_dim)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    o = ctx.constrain(o, ("batch", "seq", None))
    ckv = jnp.concatenate([c_kv, k_r[:, :, 0, :]], axis=-1)
    if seq_len_cache:
        ckv = _pad_to(ckv, seq_len_cache)
    ckv = ctx.constrain(ckv, ("batch", "kv_seq", None))
    return o, {"ckv": ckv.astype(cfg.pdtype)}


def block_prefill(cfg: ModelConfig, bc: BlockCfg, p, h, ctx: ShardCtx,
                  positions, seq_len: int, max_len: int | None = None,
                  prompt_len=None, page_size: int | None = None):
    msize = ctx.axis_size("model")
    x = rmsnorm(h, p["norm1"], cfg.norm_eps)
    if bc.mixer == "attn":
        if page_size and not bc.window:
            # paged engine: full-attention caches are sized by the *bucket*
            # (rounded up to whole pages) — the admit scatter moves them
            # into pool pages, so no max_len-row is ever materialized
            Sc = -(-seq_len // page_size) * page_size
        else:
            Sc = attn_cache_len(cfg, bc.window, max_len or seq_len, msize)
        if cfg.mla:
            y, cache = mla_prefill(cfg, p["attn"], x, ctx, positions=positions,
                                   seq_len_cache=Sc)
        else:
            y, cache = gqa_prefill(cfg, p["attn"], x, ctx, window=bc.window,
                                   positions=positions, seq_len_cache=Sc,
                                   prompt_len=prompt_len)
    else:
        mixer = (mamba_mod.mamba2_mixer if cfg.ssm.version == 2
                 else mamba_mod.mamba1_mixer)
        y, cache = mixer(cfg, p["mamba"], x, ctx, return_state=True)
    if cfg.use_post_norm:
        y = rmsnorm(y, p["post1"], cfg.norm_eps)
    h = h + y
    if bc.ffn != "none":
        x = rmsnorm(h, p["norm2"], cfg.norm_eps)
        if bc.ffn == "moe":
            y, _ = moe_mod.moe_block(cfg, p["moe"], x, ctx)
        else:
            y = mlp(cfg, p["mlp"], x, ctx)
        if cfg.use_post_norm:
            y = rmsnorm(y, p["post2"], cfg.norm_eps)
        h = h + y
    return h, cache


def prefill(cfg: ModelConfig, params, tokens, ctx: ShardCtx,
            frontend_embed=None, max_len: int | None = None,
            prompt_len=None, page_size: int | None = None):
    """tokens (B,S) → (last-token logits (B,V), cache). The lowered
    `prefill_32k` dry-run cell. `max_len` sizes the cache for further
    decoding (engine use); default = S (dry-run cell).

    `prompt_len` (B,) enables the bucketed fast path: rows are real for
    positions < prompt_len and right-padding beyond; logits are gathered at
    prompt_len-1 per row. Only valid for attention-mixer models — mamba
    state scans would absorb the pad tokens (the engine falls back to
    exact-length prefill there).

    `page_size` (paged engine): full-attention cache leaves come out sized
    `(B, ceil(S / page_size) · page_size, …)` — bucket-sized page-aligned
    rows the engine scatters into its shared pool — instead of max_len rows.
    Ring and mamba leaves are unaffected.
    """
    segments = layer_schedule(cfg)
    S = tokens.shape[1]
    h = embed(cfg, params["embed"], tokens, ctx, frontend_embed)
    positions = jnp.arange(S)
    new_blocks = []
    for seg, sp in zip(segments, params["blocks"]):

        def body(hc, slot_params, seg=seg):
            caches = {}
            for j, bc in enumerate(seg.pattern):
                hc, c = block_prefill(cfg, bc, slot_params[f"s{j}"], hc, ctx,
                                      positions, S, max_len,
                                      prompt_len=prompt_len,
                                      page_size=page_size)
                caches[f"s{j}"] = c
            return hc, caches

        body = jax.checkpoint(body, prevent_cse=False)
        h, caches = jax.lax.scan(body, h, sp)
        new_blocks.append(caches)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    h = ctx.constrain(h, ("batch", None, None))
    if prompt_len is None:
        last = h[:, -1, :]
    else:
        idx = jnp.clip(prompt_len - 1, 0, S - 1)
        last = jnp.take_along_axis(h, idx[:, None, None], axis=1)[:, 0]
    w = (params["embed"]["table"].T if cfg.tie_embeddings
         else params["unembed"]["w"])
    logits = jnp.einsum("bd,dv->bv", last, w.astype(last.dtype),
                        preferred_element_type=F32)
    logits = _softcap(logits, cfg.final_softcap)
    logits = ctx.constrain(logits, ("batch", "vocab"))
    return logits, {"blocks": new_blocks}


def whisper_prefill(cfg: ModelConfig, params, frames, ctx: ShardCtx):
    """Encode + build per-decoder-layer cross KV (the whisper prefill cell)."""
    enc_out = whisper_mod.encode(cfg, params, frames, ctx)
    enc_out = ctx.constrain(enc_out, ("batch", None, None))

    def body(_, p):
        k, v = attn_mod.cross_kv(cfg, p["cross"], enc_out, ctx)
        k = ctx.constrain(k, ("batch", "kv_seq", "kv_heads", None))
        v = ctx.constrain(v, ("batch", "kv_seq", "kv_heads", None))
        return _, {"xk": k, "xv": v}

    _, cross = jax.lax.scan(body, None, params["dec_blocks"])
    B = frames.shape[0]
    msize = ctx.axis_size("model")
    Sd = -(-cfg.max_decoder_len // msize) * msize
    zeros = jnp.zeros((cfg.n_layers, B, Sd, cfg.n_kv_heads, cfg.head_dim),
                      cfg.pdtype)
    zeros = ctx.constrain(zeros, (None, "batch", "kv_seq", "kv_heads", None))
    cache = {"dec_blocks": {"k": zeros, "v": zeros,
                            "xk": cross["xk"], "xv": cross["xv"]}}
    return enc_out, cache


def prefill_step_fn(cfg: ModelConfig, ctx: ShardCtx):
    if cfg.enc_dec:
        def step(params, frames):
            return whisper_prefill(cfg, params, frames, ctx)
        return step

    def step(params, tokens, frontend_embed=None):
        return prefill(cfg, params, tokens, ctx, frontend_embed)
    return step
