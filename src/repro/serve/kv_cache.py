"""KV-cache layouts for every architecture family.

GQA layers: (B, S_c, Hkv, dh) ×2 with the *sequence* dim sharded over
``model`` (flash-decoding; DESIGN.md §3) — batch over (pod, data). Sliding-
window layers allocate a ring buffer of exactly `window` slots (this is what
makes h2o-danube's long_500k cell cheap: 4096-slot cache at 512 k context).
MLA layers: one compressed (B, S_c, kv_lora+rope) tensor — the cache *is*
the latent. Mamba layers: O(1) conv+ssm state. Whisper: tiny self cache
(replicated S=448) + a seq-sharded cross-KV built at prefill.

The serving fast path (DESIGN.md §5) depends on these defs being sized by
the engine's `max_len` only — never by prompt length — so every prefill
bucket produces identically-shaped cache leaves and the engine's batched
insert / donated decode loop stay shape-stable across buckets.

Paged layout (DESIGN.md §5 "Paged KV cache"): full-attention leaves trade
the dense per-slot `(max_slots, S_c, …)` rows for a shared page pool
`(num_pages, page_size, …)` addressed through a per-slot page table held by
the engine; a slot only occupies the pages its context actually needs.
Ring (sliding-window) and mamba leaves keep their dense / O(1) layouts —
they are already bounded per slot. The in-page offset dim carries the
`kv_seq` logical axis, so each model shard owns a fixed sub-range of every
page and the flash-decode exact-softmax combine is unchanged.

Speculative decoding (DESIGN.md §7) layers two conventions on top without
new layouts. (1) A spec engine's cache tree is ``{"tgt": <target cache>,
"dft": <draft cache>}`` — the target side is dense or paged exactly as
above, the draft side is always dense (the draft must be full-attention,
its K/V budget is the same `max_len`, and it never shares pages with the
target). (2) The multi-token verify commit writes up to k+1 rows per slot
per round; rejected rows are deflected to **trash page 0** (the same page
every masked single-token write already lands in), so the invariant the
allocator and the property tests rely on is unchanged: live pages
(index ≥ 1) only ever receive accepted tokens, and page 0 absorbs
everything else.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models.transformer import BlockCfg, block_cfg_for_layer, layer_schedule
from repro.sharding import params as prm
from repro.sharding.params import pd


def attn_cache_len(cfg: ModelConfig, window: int, seq_len: int,
                   msize: int) -> int:
    """Ring size for windowed layers, full length otherwise; padded so the
    kv_seq dim stays divisible by the model axis."""
    S = min(window, seq_len) if window else seq_len
    return -(-S // msize) * msize


def block_cache_defs(cfg: ModelConfig, bc: BlockCfg, batch: int,
                     seq_len: int, msize: int):
    if bc.mixer == "mamba":
        fn = (mamba_mod.mamba2_state_defs if cfg.ssm.version == 2
              else mamba_mod.mamba1_state_defs)
        return fn(cfg, batch)
    Sc = attn_cache_len(cfg, bc.window, seq_len, msize)
    if cfg.mla:
        R = cfg.mla.kv_lora + cfg.mla.rope_dim
        return {"ckv": pd((batch, Sc, R), ("batch", "kv_seq", None),
                          init="zeros", dtype=cfg.pdtype)}
    return {
        "k": pd((batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "v": pd((batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
    }


def cache_defs(cfg: ModelConfig, batch: int, seq_len: int, msize: int):
    """Full decode-cache def tree, mirroring the segment structure."""
    if cfg.enc_dec:
        return encdec_cache_defs(cfg, batch, seq_len, msize)
    segments = layer_schedule(cfg)
    segs = []
    for seg in segments:
        slot = {f"s{j}": block_cache_defs(cfg, bc, batch, seq_len, msize)
                for j, bc in enumerate(seg.pattern)}
        segs.append(prm.stack(slot, seg.repeat))
    return {"blocks": segs}


# --------------------------------------------------------------- paged pool
def _is_pooled(bc: BlockCfg) -> bool:
    """Full-attention mixers go through the page pool; ring (sliding-window)
    and mamba layers keep their dense / O(1) per-slot layouts."""
    return bc.mixer == "attn" and not bc.window


def page_pool_defs(cfg: ModelConfig, num_pages: int, page_size: int):
    """Pool leaves for one full-attention layer: (num_pages, page_size, …).
    The in-page offset carries `kv_seq` so each model shard owns offsets
    [i·ps/m, (i+1)·ps/m) of every page (requires page_size % msize == 0)."""
    if cfg.mla:
        R = cfg.mla.kv_lora + cfg.mla.rope_dim
        return {"ckv": pd((num_pages, page_size, R),
                          (None, "kv_seq", None), init="zeros",
                          dtype=cfg.pdtype)}
    return {
        "k": pd((num_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                (None, "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "v": pd((num_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                (None, "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
    }


def paged_cache_defs(cfg: ModelConfig, batch: int, seq_len: int, msize: int,
                     *, num_pages: int, page_size: int):
    """Decode-cache defs with full-attention leaves replaced by page pools.
    `batch`/`seq_len` still size the dense ring / mamba leaves."""
    assert not cfg.enc_dec, "paged cache is decoder-only"
    assert page_size % msize == 0, (page_size, msize)
    segs = []
    for seg in layer_schedule(cfg):
        slot = {f"s{j}": (page_pool_defs(cfg, num_pages, page_size)
                          if _is_pooled(bc)
                          else block_cache_defs(cfg, bc, batch, seq_len,
                                                msize))
                for j, bc in enumerate(seg.pattern)}
        segs.append(prm.stack(slot, seg.repeat))
    return {"blocks": segs}


def cache_kinds(cfg: ModelConfig, *, paged: bool):
    """Per-leaf layout labels ("paged" | "dense"), structured exactly like
    the cache tree so the engine can jax.tree.map over (kinds, cache, new)."""
    segs = []
    for seg in layer_schedule(cfg):
        slot = {}
        for j, bc in enumerate(seg.pattern):
            kind = "paged" if paged and _is_pooled(bc) else "dense"
            # dummy sizes: only the tree *structure* matters here
            defs = block_cache_defs(cfg, bc, 1, 1, 1)
            slot[f"s{j}"] = prm.tree_map(lambda d, kind=kind: kind, defs)
        segs.append(slot)
    return {"blocks": segs}


def encdec_cache_defs(cfg: ModelConfig, batch: int, enc_len: int, msize: int):
    """Whisper: per-decoder-layer self cache + cross KV over encoder frames."""
    Sd = -(-cfg.max_decoder_len // msize) * msize
    Se = -(-enc_len // msize) * msize
    slot = {
        "k": pd((batch, Sd, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "v": pd((batch, Sd, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "xk": pd((batch, Se, cfg.n_kv_heads, cfg.head_dim),
                 ("batch", "kv_seq", "kv_heads", None), init="zeros",
                 dtype=cfg.pdtype),
        "xv": pd((batch, Se, cfg.n_kv_heads, cfg.head_dim),
                 ("batch", "kv_seq", "kv_heads", None), init="zeros",
                 dtype=cfg.pdtype),
    }
    return {"dec_blocks": prm.stack(slot, cfg.n_layers)}


def cache_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                msize: int) -> int:
    return prm.param_bytes(cache_defs(cfg, batch, seq_len, msize))


def page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes one page occupies across every pooled layer (HBM granularity
    of the allocator)."""
    total = 0
    for seg in layer_schedule(cfg):
        for bc in seg.pattern:
            if _is_pooled(bc):
                total += seg.repeat * prm.param_bytes(
                    page_pool_defs(cfg, 1, page_size))
    return total
