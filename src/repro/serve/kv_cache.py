"""KV-cache layouts for every architecture family.

GQA layers: (B, S_c, Hkv, dh) ×2 with the *sequence* dim sharded over
``model`` (flash-decoding; DESIGN.md §3) — batch over (pod, data). Sliding-
window layers allocate a ring buffer of exactly `window` slots (this is what
makes h2o-danube's long_500k cell cheap: 4096-slot cache at 512 k context).
MLA layers: one compressed (B, S_c, kv_lora+rope) tensor — the cache *is*
the latent. Mamba layers: O(1) conv+ssm state. Whisper: tiny self cache
(replicated S=448) + a seq-sharded cross-KV built at prefill.

The serving fast path (DESIGN.md §5) depends on these defs being sized by
the engine's `max_len` only — never by prompt length — so every prefill
bucket produces identically-shaped cache leaves and the engine's batched
insert / donated decode loop stay shape-stable across buckets.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models.transformer import BlockCfg, block_cfg_for_layer, layer_schedule
from repro.sharding import params as prm
from repro.sharding.params import pd


def attn_cache_len(cfg: ModelConfig, window: int, seq_len: int,
                   msize: int) -> int:
    """Ring size for windowed layers, full length otherwise; padded so the
    kv_seq dim stays divisible by the model axis."""
    S = min(window, seq_len) if window else seq_len
    return -(-S // msize) * msize


def block_cache_defs(cfg: ModelConfig, bc: BlockCfg, batch: int,
                     seq_len: int, msize: int):
    if bc.mixer == "mamba":
        fn = (mamba_mod.mamba2_state_defs if cfg.ssm.version == 2
              else mamba_mod.mamba1_state_defs)
        return fn(cfg, batch)
    Sc = attn_cache_len(cfg, bc.window, seq_len, msize)
    if cfg.mla:
        R = cfg.mla.kv_lora + cfg.mla.rope_dim
        return {"ckv": pd((batch, Sc, R), ("batch", "kv_seq", None),
                          init="zeros", dtype=cfg.pdtype)}
    return {
        "k": pd((batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "v": pd((batch, Sc, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
    }


def cache_defs(cfg: ModelConfig, batch: int, seq_len: int, msize: int):
    """Full decode-cache def tree, mirroring the segment structure."""
    if cfg.enc_dec:
        return encdec_cache_defs(cfg, batch, seq_len, msize)
    segments = layer_schedule(cfg)
    segs = []
    for seg in segments:
        slot = {f"s{j}": block_cache_defs(cfg, bc, batch, seq_len, msize)
                for j, bc in enumerate(seg.pattern)}
        segs.append(prm.stack(slot, seg.repeat))
    return {"blocks": segs}


def encdec_cache_defs(cfg: ModelConfig, batch: int, enc_len: int, msize: int):
    """Whisper: per-decoder-layer self cache + cross KV over encoder frames."""
    Sd = -(-cfg.max_decoder_len // msize) * msize
    Se = -(-enc_len // msize) * msize
    slot = {
        "k": pd((batch, Sd, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "v": pd((batch, Sd, cfg.n_kv_heads, cfg.head_dim),
                ("batch", "kv_seq", "kv_heads", None), init="zeros",
                dtype=cfg.pdtype),
        "xk": pd((batch, Se, cfg.n_kv_heads, cfg.head_dim),
                 ("batch", "kv_seq", "kv_heads", None), init="zeros",
                 dtype=cfg.pdtype),
        "xv": pd((batch, Se, cfg.n_kv_heads, cfg.head_dim),
                 ("batch", "kv_seq", "kv_heads", None), init="zeros",
                 dtype=cfg.pdtype),
    }
    return {"dec_blocks": prm.stack(slot, cfg.n_layers)}


def cache_bytes(cfg: ModelConfig, batch: int, seq_len: int,
                msize: int) -> int:
    return prm.param_bytes(cache_defs(cfg, batch, seq_len, msize))
