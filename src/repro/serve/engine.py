"""Continuous-batching serving engine.

Slot-based: a fixed decode batch of `max_slots` sequences; finished slots
are refilled by prefilling pending requests and inserting their caches at
the slot index. Admission control follows the paper's scheduling law: the
number of prefills admitted per cycle is an HBB chunk — the accelerator
class is the decode batch (fixed quantum), prefill admission is the
adaptive `S_c` side, driven by the measured prefill:decode throughput ratio
`f` (so a long prompt backlog can't starve decode, and vice versa).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.chunking import cpu_chunk
from repro.core.tracker import ThroughputTracker
from repro.models.model import model_defs
from repro.serve.decode import decode_step
from repro.serve.kv_cache import cache_defs
from repro.serve.prefill import prefill
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx, *,
                 max_slots: int = 4, max_len: int = 128, eos_id: int = -1):
        assert not cfg.enc_dec, "enc-dec serving uses whisper_decode_step"
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.max_slots, self.max_len, self.eos_id = max_slots, max_len, eos_id
        msize = ctx.axis_size("model")
        self.cache = prm.materialize(
            cache_defs(cfg, max_slots, max_len, msize), jax.random.PRNGKey(0))
        self.pos = np.zeros(max_slots, np.int32)       # next write position
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.pending: list[Request] = []
        self.tracker = ThroughputTracker(
            {"decode": "accelerator", "prefill": "core"}, f0=2.0)
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx))
        self._prefill = jax.jit(
            lambda p, t: prefill(cfg, p, t, ctx, max_len=max_len))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))

    # ---- cache slot insertion (jitted scatter on the batch dim) ----------
    def _insert_impl(self, cache, one_cache, slot):
        # cache leaves are (repeat, batch, …) — batch is axis 1
        def ins(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype),
                                                       slot, 1)
        return jax.tree.map(ins, cache, one_cache)

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # ---- one engine cycle -------------------------------------------------
    def step(self) -> None:
        free = self.free_slots()
        if self.pending and free:
            r = len(self.pending)
            admit = cpu_chunk(S_f=self.max_slots, f=self.tracker.f(), r=r,
                              n_cores=1)
            admit = max(1, min(admit, len(free), r))
            t0 = time.perf_counter()
            for _ in range(admit):
                req = self.pending.pop(0)
                slot = self.free_slots()[0]
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, one_cache = self._prefill(self.params, toks)
                self.cache = self._insert(self.cache, one_cache,
                                          jnp.int32(slot))
                nxt = int(jnp.argmax(logits[0]))
                req.out.append(nxt)
                self.slot_req[slot] = req
                self.pos[slot] = len(req.prompt)
            self.tracker.record("prefill", admit, time.perf_counter() - t0)

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros(self.max_slots, np.int32)
        for i in active:
            toks[i] = self.slot_req[i].out[-1]
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.tracker.record("decode", len(active), time.perf_counter() - t0)
        for i in active:
            req = self.slot_req[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(req.out) >= req.max_new or int(nxt[i]) == self.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        guard = 0
        while (self.pending or any(self.slot_req)) and guard < 10_000:
            self.step()
            guard += 1
        return requests


def make_engine(cfg: ModelConfig, ctx: ShardCtx, seed: int = 0,
                **kw) -> Engine:
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    return Engine(cfg, params, ctx, **kw)
