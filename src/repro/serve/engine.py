"""Continuous-batching serving engine.

Slot-based: a fixed decode batch of `max_slots` sequences; finished slots
are refilled by prefilling pending requests and inserting their caches at
the slot index. Admission control follows the paper's scheduling law: the
accelerator class is the fused decode quantum (fixed `S_f`), prefill
admission is the adaptive `S_c` side, driven by the measured
prefill:decode *token* throughput ratio `f` (so a long prompt backlog
can't starve decode, and vice versa).

Fast path (default; DESIGN.md §"Serving fast path"):
  * decode runs `decode_quantum` tokens per dispatch via a jitted
    `lax.scan` with on-device argmax and per-slot done masking — exactly
    one blocking host fetch per quantum (tokens, masks and the post-quantum
    active vector come back as a single packed array);
  * the KV cache and (tokens, pos, active, remaining) state vectors stay
    resident on device and are *donated* through the decode loop, so a
    decode step updates the cache in place instead of allocating a new one;
  * prompts are padded to power-of-2 length buckets and prefilled batched
    (fixed batch `prefill_batch`), then inserted with a single gather-based
    scatter — one XLA compile per bucket, one dispatch per admitted group.

Paged KV cache (`paged=True`; DESIGN.md §5 "Paged KV cache"): full-attention
cache leaves live in a shared page pool `(num_pages, page_size, …)` indexed
through a per-slot page table, with a host-side free-list allocator — pages
are granted at admission, topped up ahead of each decode quantum, and
recycled when a request completes, so short requests stop stranding
max_len-sized cache rows. Ring and mamba layers keep their dense layouts.
Paged decode attention runs the Pallas paged flash-decode kernel by default
(`paged_kernel=True`; `kernels/paged_attention`): the page table is indexed
*in-kernel* and the engine hands the decode loop only the table's *live*
page-column prefix (bucketed to powers of two to bound recompiles), so
per-token attention cost scales with actual context instead of the table
width `max_len/page_size`. `paged_kernel=False` pins the jnp gathered-view
implementation at full table width — the PR 2 cost model — as the escape
hatch.

Sampling: `temperature=0` (default) is greedy argmax; `temperature>0`
enables on-device temperature/top-k/top-p categorical sampling with the
PRNG key carried through the decode scan (still exactly one host sync per
quantum).

Speculative decoding (`draft_cfg=` + `spec_k=`; DESIGN.md §7): a little
draft model proposes spec_k tokens per round inside the decode quantum and
the big target verifies all spec_k+1 positions in ONE batched pass —
the model-level analogue of the paper's little-cores-assist-big-accelerator
split. Greedy traffic is token-identical to target-only decoding; sampled
traffic is distribution-preserving (rejection sampling). The engine carries
a combined {"tgt", "dft"} cache, accounts *accepted* tokens per quantum
(`StepReport.accepted/proposed`), and its measured tok/s is therefore
acceptance-scaled — exactly the effective-throughput signal the
multi-tier routing law wants.

`fast=False` keeps the original per-token / per-prompt reference path; the
benchmark (benchmarks/bench_serve.py) and the equivalence tests in
tests/test_serve.py run both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.core.chunking import cpu_chunk
from repro.kernels.paged_attention import ops as paged_ops
from repro.core.tracker import ThroughputTracker
from repro.models.model import model_defs
from repro.models.transformer import layer_schedule
from repro.serve.decode import _sample_tokens, decode_loop_fn, decode_step
from repro.serve.kv_cache import cache_defs, cache_kinds, paged_cache_defs
from repro.serve.prefill import bucket_len, prefill
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx, mesh_axis_size


class PromptTooLongError(ValueError):
    """Raised at ``submit()`` for a prompt the engine can never schedule.

    A prompt of ``n`` tokens needs at least one decode slot after prefill,
    so ``n`` must be strictly less than the engine's ``max_len``. Raised
    eagerly at submission (not mid-serve) so callers can route the request
    to a longer-context engine — ``MultiEngine`` checks every tier before
    accepting. Subclasses :class:`ValueError`.
    """


class EngineStallError(RuntimeError):
    """``run()``/``drain()`` made no forward progress for far longer than
    the outstanding workload warrants.

    The cycle guard is proportional to queued work (one admission cycle
    per request plus ``max_new / decode_quantum`` decode cycles, with 8×
    slack — see ``Engine._guard_limit``), so this indicates a scheduling
    bug or slot/pool starvation rather than a slow model. The message
    reports pending and unfinished request counts; ``MultiEngine`` raises
    it with per-tier diagnostics *after* reclaiming every tier's slots and
    pages (failure hygiene — DESIGN.md §8), so catching it leaves a clean,
    reusable pool. Subclasses :class:`RuntimeError`.
    """


class RequestFailedError(RuntimeError):
    """Terminal per-request failure: the request exhausted its retry
    budget (or the pool stalled) and was dead-lettered instead of being
    retried forever.

    Never raised out of ``MultiEngine.run`` — a sick request must not
    poison the pool or abort its batch-mates. Instead the pool records an
    instance in ``MultiEngine.dead_letters[rid]`` and stops tracking the
    request; ``Request.done`` stays False and ``Request.out`` holds
    whatever prefix was emitted before the final failure. Subclasses
    :class:`RuntimeError`.
    """


def worst_case_pages(prompt_len: int, max_new: int, decode_quantum: int,
                     max_len: int, page_size: int) -> int:
    """Worst-case pages a request can ever be granted: its context can reach
    prompt+max_new-1, plus quantum-granularity slack for the frozen-slot
    scribble positions, all capped at max_len. Shared with the benchmark's
    pool sizing so the two can't drift."""
    end = min(prompt_len + max_new - 1 + decode_quantum, max_len)
    return max(1, -(-end // page_size))


def _host_fetch(x) -> np.ndarray:
    """Every device→host read on the fast path goes through here, so tests
    can monkeypatch it as a fetch-count probe (one call per decode quantum,
    one per admitted prefill group)."""
    return np.asarray(x)


@dataclass
class Request:
    """One generation request.

    Attributes:
      rid: caller-chosen id (engines never interpret it; benchmarks and
        multi-tier routing logs key on it).
      prompt: token ids to prefill. Must be non-empty and shorter than the
        serving engine's ``max_len``.
      max_new: decode budget — the stream stops after this many generated
        tokens (the first is sampled at prefill), at EOS, or at the
        context limit, whichever comes first.
      out: generated token ids, appended as quanta complete.
      done: set by the engine when the stream is finished.
    """
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class StepReport:
    """What one engine cycle did — the tier-facing throughput surface.

    ``MultiEngine`` feeds ``(decoded, dt)`` of warm cycles into the shared
    cross-tier :class:`~repro.core.tracker.ThroughputTracker`, which is
    what the routing law measures per-tier tok/s from; single-engine
    callers are free to ignore the return value (PR ≤ 3 behaviour).

    Attributes:
      admitted: requests moved from pending into slots this cycle.
      decoded: decode tokens *emitted* across all slots this cycle. For a
        speculative engine one scan round can emit up to spec_k+1 tokens;
        `decoded` counts emissions (acceptance-scaled), never rounds, so
        `decoded / dt` is the *effective* tok/s the routing law should see
        and multi-token steps cannot inflate it.
      dt: wall seconds of the decode quantum dispatch (device interval;
        host-side bookkeeping excluded).
      warm: False when the quantum triggered a fresh XLA compile — such
        intervals measure the compiler, not the tier, and must not be fed
        to a throughput tracker.
      accepted: draft proposals the target verifier accepted this cycle
        (0 for non-speculative engines).
      proposed: draft proposals made this cycle (spec_k per active round);
        accepted/proposed is the acceptance rate.
    """
    admitted: int = 0
    decoded: int = 0
    dt: float = 0.0
    warm: bool = True
    accepted: int = 0
    proposed: int = 0


def _jit_cache_size(fn) -> int:
    """Compile-count probe: distinct traced signatures of a jitted fn."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class PageAllocator:
    """Host-side free-list allocator over the shared KV page pool.

    Page 0 is a reserved scratch ("trash") page: page-table rows of empty
    slots point at it, so the masked scribbles of inactive decode rows can
    never touch a live page. Admission reserves a worst-case page budget
    (`commit`) per request up front; pages are physically handed out lazily
    (`grow_to`) as the context crosses page boundaries. The invariant
    `sum(committed - count) <= len(free)` makes every grow_to infallible —
    pool pressure surfaces only as admission backpressure (`can_commit`).
    """

    def __init__(self, num_pages: int, max_slots: int, pages_per_slot: int):
        if num_pages - 1 < pages_per_slot:
            raise ValueError(
                f"pool of {num_pages} pages (1 reserved) cannot hold one "
                f"full {pages_per_slot}-page context")
        self.num_pages = num_pages
        self.free = list(range(num_pages - 1, 0, -1))   # pop() → low pages
        self.table = np.zeros((max_slots, pages_per_slot), np.int32)
        self.count = np.zeros(max_slots, np.int32)      # pages held per slot
        self.committed = np.zeros(max_slots, np.int32)  # worst-case budget
        self.min_free = len(self.free)                  # high-water telemetry
        self.total_grants = 0                           # page reuse evidence

    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    def outstanding(self) -> int:
        """Pages promised to live slots but not yet handed out."""
        return int((self.committed - self.count).sum())

    def can_commit(self, n_pages: int) -> bool:
        return len(self.free) - self.outstanding() >= n_pages

    def commit(self, slot: int, n_pages: int) -> None:
        if self.committed[slot] or self.count[slot]:
            raise RuntimeError(f"slot {slot} already holds pages")
        if not self.can_commit(n_pages):
            raise RuntimeError(
                f"admitted past pool capacity ({n_pages} pages, "
                f"{len(self.free)} free, {self.outstanding()} outstanding)")
        self.committed[slot] = n_pages

    def grow_to(self, slot: int, n_pages: int) -> None:
        if n_pages > self.committed[slot]:
            raise RuntimeError(
                f"slot {slot}: grant of {n_pages} pages exceeds the "
                f"committed budget {int(self.committed[slot])}")
        while self.count[slot] < n_pages:
            self.table[slot, self.count[slot]] = self.free.pop()
            self.count[slot] += 1
            self.total_grants += 1
        self.min_free = min(self.min_free, len(self.free))

    def release(self, slot: int) -> None:
        for t in range(int(self.count[slot])):
            self.free.append(int(self.table[slot, t]))
        self.table[slot, :] = 0                         # back to trash page
        self.count[slot] = 0
        self.committed[slot] = 0

    def check(self) -> None:
        """Pool conservation invariant: every usable page is exactly once
        either on the free list or held by exactly one slot — no leaks,
        no double-frees, no aliased grants. Raises :class:`RuntimeError`
        naming the offending pages. Cheap (host ints); the fault-injection
        suite asserts it after every drain/abort, and callers recovering
        from a tier failure may call it before reusing the engine."""
        held = [int(self.table[s, t])
                for s in range(self.table.shape[0])
                for t in range(int(self.count[s]))]
        seen = sorted(self.free + held)
        want = list(range(1, self.num_pages))
        if seen != want:
            from collections import Counter
            c = Counter(seen)
            dup = sorted(p for p, k in c.items() if k > 1)
            lost = sorted(set(want) - set(c))
            bad = sorted(set(seen) - set(want))
            raise RuntimeError(
                f"page pool invariant violated: leaked={lost} "
                f"double-held={dup} out-of-range={bad}")
        if any(self.count[s] > self.committed[s]
               for s in range(len(self.count))):
            raise RuntimeError(
                f"page pool invariant violated: a slot holds more pages "
                f"than its commit (count={self.count.tolist()}, "
                f"committed={self.committed.tolist()})")


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx, *,
                 max_slots: int = 4, max_len: int = 128, eos_id: int = -1,
                 decode_quantum: int = 8, prefill_batch: int | None = None,
                 min_bucket: int = 16, fast: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: int | None = None, paged_kernel=True,
                 temperature: float = 0.0, top_k: int = 0,
                 top_p: float = 0.0, sample_seed: int = 0,
                 draft_cfg: ModelConfig | None = None, draft_params=None,
                 spec_k: int = 0, step_deadline_s: float | None = None):
        """Build a serving engine over an existing parameter tree.

        Args:
          cfg: model config (any decoder-only family; enc-dec audio serves
            through ``whisper_decode_step`` instead).
          params: parameter tree from ``prm.materialize(model_defs(cfg))``
            — may be shared (read-only) across several engines, which is
            how ``MultiEngine`` builds token-equivalent tiers.
          ctx: sharding context; the KV cache is mesh-placed at init.
          max_slots: decode batch width — concurrent streams.
          max_len: per-slot context capacity (prompt + generated tokens).
            Prompts must be strictly shorter (``PromptTooLongError``).
          eos_id: token id that ends a stream (-1: never).
          decode_quantum: tokens decoded per fused dispatch; the host syncs
            exactly once per quantum. Also the fixed accelerator chunk
            ``S_f`` of the HBB admission law.
          prefill_batch: rows per batched prefill dispatch (default
            ``max_slots``).
          min_bucket: smallest power-of-2 prompt-length bucket; one XLA
            compile per bucket, not per distinct prompt length.
          fast: False pins the original per-token reference path (greedy
            only; baselines and equivalence tests).
          paged: serve full-attention KV from a shared page pool with a
            per-slot page table instead of dense ``max_slots × max_len``
            rows (DESIGN.md §5). Requires ``fast=True`` and an unsharded
            batch axis; rings/mamba state stay dense either way.
          page_size: tokens per KV page; must divide ``max_len`` and be a
            multiple of the model-axis size.
          num_pages: pool size including the reserved trash page 0
            (default: enough for every slot at full ``max_len``). Sizing
            it *below* the worst case is the point — admission exerts
            backpressure instead of stranding HBM.
          paged_kernel: True (default) walks the page table *in-kernel*
            (Pallas on TPU, the fused blockwise reference on CPU) so
            decode cost follows live context; False pins the jnp
            gathered-view escape hatch at full table width (the PR 2 cost
            model / equivalence oracle). A string names a
            ``kernels/paged_attention`` impl explicitly (e.g.
            ``"interpret"``).
          temperature: 0 (default) decodes greedy argmax; > 0 samples a
            temperature-scaled categorical on device (PRNG key rides the
            decode scan carry — still one host sync per quantum).
          top_k: truncate sampling to the k most likely tokens (0: off;
            1 collapses to greedy regardless of seed).
          top_p: nucleus sampling — truncate to the smallest token set
            whose probability mass reaches top_p (0 or 1.0: off, and
            traces to the identical jaxpr as the pre-nucleus sampler).
          sample_seed: PRNG seed for sampling; same seed → same streams.
          draft_cfg: little proposal model for speculative decoding
            (None: off). Must be decoder-only, full-attention with no
            sliding window (its dense cache is written optimistically and
            stale rows must stay invalid until overwritten), and share the
            target's vocab. Requires ``fast=True``.
          draft_params: the draft's parameter tree; None materializes
            fresh ones from ``draft_cfg`` (tests / toy tiers —
            ``models/draft.py`` builds an aligned big/little pair from the
            target's own weights).
          spec_k: draft proposals per verify round (≥ 1 with a draft).
            Each decode-scan round emits between 1 and spec_k+1 tokens;
            greedy output is token-identical to ``spec_k=0`` serving.
          step_deadline_s: advisory wall-clock budget for one ``step()``
            (None: unbounded). The engine itself never preempts a quantum
            — XLA dispatches are not interruptible — but a supervisor
            (``MultiEngine``'s per-tier watchdog, DESIGN.md §8) reads this
            to decide when a step has hung and the tier should be
            quarantined.
        """
        assert not cfg.enc_dec, "enc-dec serving uses whisper_decode_step"
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.max_slots, self.max_len, self.eos_id = max_slots, max_len, eos_id
        self.fast = fast
        if step_deadline_s is not None and step_deadline_s <= 0:
            raise ValueError(f"step_deadline_s must be positive or None, "
                             f"got {step_deadline_s}")
        self.step_deadline_s = step_deadline_s
        self.decode_quantum = max(1, decode_quantum)
        if temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if not 0 <= top_k <= cfg.vocab:
            raise ValueError(f"top_k must be in [0, vocab={cfg.vocab}], "
                             f"got {top_k}")
        if temperature and not fast:
            raise ValueError("sampling (temperature > 0) requires fast=True "
                             "— the legacy reference path is greedy only")
        if not 0.0 <= top_p <= 1.0:
            raise ValueError(f"top_p must be in [0, 1], got {top_p}")
        self.temperature, self.top_k = float(temperature), int(top_k)
        self.top_p = float(top_p)
        # ---- speculative decode (draft/verify) validation ----------------
        self._spec = draft_cfg is not None
        if spec_k and not self._spec:
            raise ValueError("spec_k requires a draft_cfg")
        self.spec_k = int(spec_k)
        self.draft_cfg = draft_cfg
        self.tokens_per_step = (self.spec_k + 1) if self._spec else 1
        if self._spec:
            if not fast:
                raise ValueError("speculative decode requires fast=True")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1 with a draft, got "
                                 f"{spec_k}")
            if draft_cfg.enc_dec:
                raise ValueError("draft must be decoder-only")
            if draft_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab} != target vocab "
                    f"{cfg.vocab} — proposals must be target token ids")
            for seg in layer_schedule(draft_cfg):
                for bc in seg.pattern:
                    if bc.mixer != "attn" or bc.window:
                        raise ValueError(
                            "draft must be full-attention with no sliding "
                            "window: its cache rows are written "
                            "optimistically, which is only sound when "
                            "validity is gpos <= pos on a dense cache")
            windows = [bc.window for seg in layer_schedule(cfg)
                       for bc in seg.pattern
                       if bc.mixer == "attn" and bc.window]
            if windows and min(windows) < spec_k + 1:
                raise ValueError(
                    f"spec_k+1 = {spec_k + 1} verify rows exceed the "
                    f"target's smallest window {min(windows)} — staged "
                    f"rows must all be in-window for every verify query")
        self.spec_accepted = 0                 # lifetime acceptance counters
        self.spec_proposed = 0
        if isinstance(paged_kernel, (bool, int)):
            paged_kernel = bool(paged_kernel)   # 0/1 → canonical bools
        elif paged_kernel not in paged_ops._IMPLS:
            raise ValueError(
                f"paged_kernel must be a bool or one of {paged_ops._IMPLS}, "
                f"got {paged_kernel!r}")
        self.paged_kernel = paged_kernel
        self.prefill_batch = prefill_batch or max_slots
        self.min_bucket = min_bucket
        # padded buckets are only sound when every mixer is attention —
        # a mamba state scan would absorb the pad tokens (DESIGN.md)
        self.pad_safe = all(bc.mixer == "attn"
                            for seg in layer_schedule(cfg)
                            for bc in seg.pattern)
        msize = ctx.axis_size("model")
        self.paged = bool(paged)
        cache_d = None
        if self.paged:
            if not fast:
                raise ValueError("paged KV cache requires fast=True")
            if mesh_axis_size(ctx.mesh, ("pod", "data")) > 1:
                # pool leaves are replicated over the batch axes but written
                # per-slot under check_rep=False — replicas would silently
                # diverge; data-parallel paged pools are a ROADMAP follow-on
                raise ValueError("paged KV cache requires an unsharded "
                                 "batch axis (data/pod mesh size 1)")
            if page_size <= 0 or page_size % msize:
                raise ValueError(
                    f"page_size {page_size} must be a positive multiple of "
                    f"the model-axis size {msize}")
            if max_len % page_size:
                raise ValueError(
                    f"max_len {max_len} must be a multiple of page_size "
                    f"{page_size}")
            self.page_size = page_size
            self.pages_per_slot = max_len // page_size
            self.num_pages = num_pages or 1 + max_slots * self.pages_per_slot
            self.alloc = PageAllocator(self.num_pages, max_slots,
                                       self.pages_per_slot)
            cache_d = paged_cache_defs(cfg, max_slots, max_len, msize,
                                       num_pages=self.num_pages,
                                       page_size=page_size)
            self.page_table_dev = jnp.asarray(self.alloc.table)
            self._table_dirty = False
            self.pos_host = np.zeros(max_slots, np.int64)  # device-pos mirror
        else:
            cache_d = cache_defs(cfg, max_slots, max_len, msize)
        if self._spec:
            # combined tree: the draft always serves from a dense cache
            # (optimistic writes are only sound there — see above)
            cache_d = {"tgt": cache_d,
                       "dft": cache_defs(draft_cfg, max_slots, max_len,
                                         msize)}
        # place the cache on the mesh up front: the donated decode loop
        # emits mesh-sharded leaves, and a fresh SingleDeviceSharding cache
        # would make every admit bucket compile twice (once per sharding).
        # single-device meshes get the replicated spec the loop actually
        # emits; real meshes get the defs' kv_seq shardings (replicating a
        # pool across the model axis would forfeit the HBM the pool saves)
        self.cache = prm.materialize(cache_d, jax.random.PRNGKey(0))
        if ctx.mesh.size == 1:
            self.cache = jax.device_put(
                self.cache, NamedSharding(ctx.mesh, PartitionSpec()))
        else:
            self.cache = jax.tree.map(jax.device_put, self.cache,
                                      prm.shardings(cache_d, ctx))
        self.kinds = cache_kinds(cfg, paged=self.paged)
        if self._spec:
            if draft_params is None:
                draft_params = prm.materialize(model_defs(draft_cfg),
                                               jax.random.PRNGKey(0))
            self.draft_params = draft_params
            self.kinds = {"tgt": self.kinds,
                          "dft": cache_kinds(draft_cfg, paged=False)}
            self._loop_params = {"tgt": params, "dft": draft_params}
        else:
            self.draft_params = None
            self._loop_params = params
        self.pos = np.zeros(max_slots, np.int32)       # legacy-path mirror
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.pending: list[Request] = []
        self.tracker = ThroughputTracker(
            {"decode": "accelerator", "prefill": "core"}, f0=2.0)
        self.cycle_log: list[dict] = []                # per-cycle balance
        self._last_admitted = 0
        self.quanta = 0                                # decode dispatches
        self.prefill_groups = 0                        # prefill dispatches
        # device-resident decode state (fast path), mesh-placed like cache
        repl = NamedSharding(ctx.mesh, PartitionSpec())
        self.tokens_dev = jax.device_put(jnp.zeros(max_slots, jnp.int32),
                                         repl)
        self.pos_dev = jax.device_put(jnp.zeros(max_slots, jnp.int32), repl)
        self.active_dev = jax.device_put(jnp.zeros(max_slots, bool), repl)
        self.remaining_dev = jax.device_put(jnp.zeros(max_slots, jnp.int32),
                                            repl)
        self.rng_dev = jax.device_put(jax.random.PRNGKey(sample_seed), repl)
        # independent stream for first-token sampling at prefill (split per
        # admitted group on host — a device op, not a blocking fetch)
        self._prefill_rng = jax.random.PRNGKey(sample_seed + 1)
        # ---- jitted cells -------------------------------------------------
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx))
        self._prefill = jax.jit(
            lambda p, t: prefill(cfg, p, t, ctx, max_len=max_len))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._decode_loop = jax.jit(
            decode_loop_fn(cfg, ctx, num_steps=self.decode_quantum,
                           eos_id=eos_id, max_len=max_len, paged=self.paged,
                           paged_kernel=self.paged_kernel,
                           temperature=self.temperature, top_k=self.top_k,
                           top_p=self.top_p, draft_cfg=draft_cfg,
                           spec_k=self.spec_k),
            donate_argnums=(1, 2, 3, 4, 5, 6))
        self._prefill_fast = jax.jit(self._prefill_fast_impl)
        self._admit = jax.jit(
            self._admit_paged_impl if self.paged else self._admit_impl,
            donate_argnums=(0, 1, 2, 3, 4))

    # ---- cache slot insertion (jitted scatter on the batch dim) ----------
    def _insert_impl(self, cache, one_cache, slot):
        # cache leaves are (repeat, batch, …) — batch is axis 1
        def ins(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype),
                                                       slot, 1)
        return jax.tree.map(ins, cache, one_cache)

    # ---- fast path: batched prefill + fused admission --------------------
    def _prefill_fast_impl(self, params, toks, prompt_len, key):
        """(P,Sb) padded prompts → (first sampled token (P,), batched
        cache). Sampling (greedy at temperature=0) happens on device so
        admission never ships logits home — the first token of a stream
        follows the same temperature/top-k/top-p law as the decode loop.
        Speculative engines prefill the draft too (its logits are unused;
        only its cache matters) and return the combined tree."""
        tp = params["tgt"] if self._spec else params
        logits, cache = prefill(self.cfg, tp, toks, self.ctx,
                                max_len=self.max_len, prompt_len=prompt_len,
                                page_size=(self.page_size if self.paged
                                           else None))
        if self._spec:
            _, dcache = prefill(self.draft_cfg, params["dft"], toks,
                                self.ctx, max_len=self.max_len,
                                prompt_len=prompt_len)
            cache = {"tgt": cache, "dft": dcache}
        first = _sample_tokens(logits, key, temperature=self.temperature,
                               top_k=self.top_k, top_p=self.top_p)
        return first, cache

    def _admit_state(self, tokens, pos, active, remaining, hit, idx,
                     first, prompt_len, max_new):
        """Blend the prefilled rows' scalar state into the slot vectors."""
        pl = jnp.take(prompt_len, idx)
        rem = jnp.take(max_new, idx) - 1       # prefill already emitted one
        tokens = jnp.where(hit, jnp.take(first, idx), tokens)
        pos = jnp.where(hit, pl, pos)
        remaining = jnp.where(hit, rem, remaining)
        # pl == max_len-1 still gets one decode step (writes the last cache
        # slot) — matches the legacy path's post-step done check
        active = jnp.where(hit, (rem > 0) & (pl < self.max_len), active)
        return tokens, pos, active, remaining

    def _admit_sel(self, slots, valid):
        """slot-targeting mask/index pair for the gather-formulated scatter:
        for each engine slot s, the (at most one) prefill row targeting s."""
        S = self.max_slots
        sel = valid[None, :] & (slots[None, :] == jnp.arange(S)[:, None])
        return sel.any(axis=1), jnp.argmax(sel, axis=1)

    def _admit_impl(self, cache, tokens, pos, active, remaining, new_cache,
                    first, prompt_len, max_new, slots, valid):
        """Scatter a prefilled batch into its engine slots in ONE dispatch.

        Formulated as a gather so it stays shape-stable under jit: for each
        engine slot s, pick the (at most one) prefill row targeting s and
        blend it into every cache leaf / state vector.
        """
        S = self.max_slots
        hit, idx = self._admit_sel(slots, valid)

        def ins(c, o):
            g = jnp.take(o, idx, axis=1)       # (repeat, S, …)
            m = hit.reshape((1, S) + (1,) * (c.ndim - 2))
            return jnp.where(m, g.astype(c.dtype), c)

        cache = jax.tree.map(ins, cache, new_cache)
        return (cache,) + self._admit_state(tokens, pos, active, remaining,
                                            hit, idx, first, prompt_len,
                                            max_new)

    def _admit_paged_impl(self, cache, tokens, pos, active, remaining,
                          new_cache, first, prompt_len, max_new, slots,
                          valid, page_src):
        """Paged admit: dense leaves (rings, mamba state) blend per slot as
        in `_admit_impl`; pool leaves scatter the bucket-sized prefill rows
        into their freshly allocated pages. `page_src` (num_pages,) int32 is
        host-computed: flat (row · pages_per_row + page) source index for
        each pool page, or -1 for pages this group doesn't touch."""
        S = self.max_slots
        hit, idx = self._admit_sel(slots, valid)

        def ins(kind, c, o):
            if kind == "paged":
                # c (repeat, N, ps, …) pool; o (repeat, P, Tb·ps, …) rows
                ps, N = c.shape[2], c.shape[1]
                rep, Pb = o.shape[0], o.shape[1]
                Tb = o.shape[2] // ps
                src = o.reshape((rep, Pb * Tb, ps) + o.shape[3:])
                g = jnp.take(src, jnp.clip(page_src, 0, Pb * Tb - 1), axis=1)
                m = (page_src >= 0).reshape((1, N) + (1,) * (c.ndim - 2))
                return jnp.where(m, g.astype(c.dtype), c)
            g = jnp.take(o, idx, axis=1)       # (repeat, S, …)
            m = hit.reshape((1, S) + (1,) * (c.ndim - 2))
            return jnp.where(m, g.astype(c.dtype), c)

        cache = jax.tree.map(ins, self.kinds, cache, new_cache)
        return (cache,) + self._admit_state(tokens, pos, active, remaining,
                                            hit, idx, first, prompt_len,
                                            max_new)

    def submit(self, req: Request) -> None:
        n = len(req.prompt)
        if n == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if n >= self.max_len:
            raise PromptTooLongError(
                f"request {req.rid}: prompt of {n} tokens needs at least "
                f"one decode slot; engine max_len is {self.max_len}")
        self.pending.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def prefill_compiles(self) -> int:
        """Distinct prefill compiles so far (fast: one per length bucket)."""
        return _jit_cache_size(self._prefill_fast if self.fast
                               else self._prefill)

    def reserved_cache_bytes(self) -> int:
        """Persistently reserved KV-cache HBM (pool + dense leaves)."""
        return sum(int(x.nbytes) for x in jax.tree.leaves(self.cache))

    # ---- tier-facing interface (submit / step / drain) -------------------
    # MultiEngine treats an Engine as one resource of the paper's CC/FC
    # pool: it probes capacity, hands over queued requests, steps it, and
    # reclaims whatever the engine's own admission law left pending.
    def has_work(self) -> bool:
        """True while any request is pending or occupies a decode slot."""
        return bool(self.pending) or any(r is not None for r in self.slot_req)

    def take_pending(self) -> list[Request]:
        """Hand back the not-yet-admitted queue (admitted requests stay —
        their KV lives in this engine's cache). A multi-tier router calls
        this after each cycle so work an engine could not admit (slot or
        pool backpressure) reroutes instead of queueing behind it."""
        out, self.pending = self.pending, []
        return out

    def plan_admission(self, reqs: list[Request]) -> int:
        """How many of ``reqs`` (a prefix, in order) this engine could admit
        right now: bounded by free slots net of already-pending work and,
        for paged engines, by the pool's worst-case commit budget. Purely
        advisory — submission still goes through ``submit()`` — but it lets
        a router keep work off a tier that cannot take it."""
        n = min(len(reqs), len(self.free_slots()) - len(self.pending))
        if n <= 0:
            return 0
        if not self.paged:
            return n
        # already-pending requests will commit their worst case first —
        # count them against the pool before promising capacity for more
        planned = sum(self._worst_pages(r) for r in self.pending)
        k = 0
        for req in reqs[:n]:
            w = self._worst_pages(req)
            if not self.alloc.can_commit(planned + w):
                break
            planned += w
            k += 1
        return k

    def decode_throughput(self) -> float:
        """EWMA decode tokens/sec this engine has measured for itself (0.0
        until the first warm quantum). The cross-tier router prefers the
        shared tracker it feeds from :class:`StepReport`; this accessor is
        for introspection and examples."""
        return self.tracker.throughput("decode")

    def drain(self) -> None:
        """Step until no pending or admitted work remains (same stall guard
        as ``run()``). Tier-facing shutdown: a router that stops routing to
        this engine can still let admitted streams finish."""
        guard, limit = 0, self._guard_limit()
        while self.has_work():
            if guard >= limit:
                raise EngineStallError(
                    f"drain made no progress after {guard} cycles "
                    f"(limit {limit}): {len(self.pending)} pending")
            self.step()
            guard += 1

    def abort(self) -> list:
        """Failure-safe reclaim of every *admitted* request (DESIGN.md §8).

        Empties the decode slots without stepping the model: each in-flight
        request is handed back with whatever tokens it already emitted
        (``Request.out`` is preserved — the resume-from-emitted retry law
        re-prefills from prompt+out), its pages are released, and the
        device-side active/remaining vectors are zeroed so a later admit
        meets the same inactive slots a fresh engine has. The KV cache
        contents are left as-is — inactive slots never read them, dense
        rows are fully overwritten at the next admit, and released pages
        re-enter the free list (table rows point back at trash page 0).

        Host-side bookkeeping only — safe to call even when the engine's
        last ``step()`` raised mid-quantum. Pending (never-admitted)
        requests are NOT included; callers wanting those too should call
        ``take_pending()`` first. Returns the reclaimed requests in slot
        order."""
        out = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            out.append(req)
            self.slot_req[i] = None
            if self.paged:
                self._release_slot_pages(i)
                self.pos_host[i] = 0
            self.pos[i] = 0                        # legacy-path mirror
        if self.paged:
            self._push_page_table()
        if self.fast:
            repl = NamedSharding(self.ctx.mesh, PartitionSpec())
            self.active_dev = jax.device_put(
                jnp.zeros(self.max_slots, bool), repl)
            self.remaining_dev = jax.device_put(
                jnp.zeros(self.max_slots, jnp.int32), repl)
        return out

    # ---- paged-pool bookkeeping ------------------------------------------
    @property
    def quantum_tokens(self) -> int:
        """Most tokens one decode quantum can advance a slot: every scan
        round emits up to ``tokens_per_step`` (1, or spec_k+1 for a
        speculative engine). Page grants and the live-table slice budget
        this worst case — acceptance below 100% just leaves slack."""
        return self.decode_quantum * self.tokens_per_step

    def _worst_pages(self, req: Request) -> int:
        return worst_case_pages(len(req.prompt), req.max_new,
                                self.quantum_tokens, self.max_len,
                                self.page_size)

    def _grant_quantum_pages(self, active_slots: list[int]) -> None:
        """Pre-grant every occupied slot enough pages to cover the coming
        quantum, so the decode loop never needs a device-side allocator."""
        for i in active_slots:
            end = min(int(self.pos_host[i]) + self.quantum_tokens,
                      self.max_len)
            target = -(-end // self.page_size)
            if target > self.alloc.count[i]:
                self.alloc.grow_to(i, target)
                self._table_dirty = True

    def _release_slot_pages(self, slot: int) -> None:
        self.alloc.release(slot)
        self._table_dirty = True

    def _push_page_table(self) -> None:
        if self._table_dirty:
            self.page_table_dev = jnp.asarray(self.alloc.table)
            self._table_dirty = False

    def _live_page_table(self, active_slots: list[int]):
        """Page-table view handed to the decode loop. The kernel path gets
        only the *live* column prefix — enough pages to cover every active
        slot through the coming quantum, rounded up to a power of two so the
        loop compiles once per bucket, not once per context length, and
        floored at 8 pages: sub-8 buckets save nothing measurable but
        multiply compiles (an all-short admission wave would mint a fresh
        bucket mid-serve). The gather path keeps the full table (the PR 2
        escape hatch stays byte-identical). Slots whose stale `pos` exceeds
        the sliced width are routed to the trash page by `_paged_write`'s
        range guard."""
        if not self.paged_kernel:
            return self.page_table_dev
        end = max(min(int(self.pos_host[i]) + self.quantum_tokens,
                      self.max_len) for i in active_slots)
        n_live = max(-(-end // self.page_size), 8)
        n_live = min(self.pages_per_slot, 1 << (n_live - 1).bit_length())
        if n_live == self.pages_per_slot:  # full width → no slice dispatch
            return self.page_table_dev
        return self.page_table_dev[:, :n_live]

    # ---- one engine cycle -------------------------------------------------
    def step(self) -> StepReport:
        """One engine cycle: admit pending prompts (HBB token budget), run
        one decode quantum, retire finished slots. Returns a
        :class:`StepReport` so a multi-tier router can measure this
        engine's per-quantum token throughput without reaching into its
        private tracker."""
        if not self.fast:
            return self._step_legacy()
        self._last_admitted = 0
        free = self.free_slots()
        if self.pending and free:
            self._admit_pending(free)
        active_slots = [i for i, r in enumerate(self.slot_req)
                        if r is not None]
        if not active_slots:
            if self._last_admitted:   # everything finished at prefill —
                self.cycle_log.append({"admitted": self._last_admitted,
                                       "decoded": 0,
                                       "f": self.tracker.f()})
            return StepReport(admitted=self._last_admitted)
        if self.paged:
            self._grant_quantum_pages(active_slots)
            self._push_page_table()
        t0 = time.perf_counter()
        n0 = _jit_cache_size(self._decode_loop)
        args = (self._loop_params, self.cache, self.tokens_dev,
                self.pos_dev, self.active_dev, self.remaining_dev,
                self.rng_dev)
        if self.paged:
            carry, packed = self._decode_loop(
                *args, self._live_page_table(active_slots))
        else:
            carry, packed = self._decode_loop(*args)
        (self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
         self.remaining_dev, self.rng_dev) = carry
        packed_h = _host_fetch(packed)         # the ONE host sync per quantum
        dt = time.perf_counter() - t0
        self.quanta += 1
        N = self.decode_quantum
        # a speculative round can emit up to tokens_per_step tokens, so the
        # packed array carries N·K emission rows (round-major, in order)
        NK = N * self.tokens_per_step
        toks_h = packed_h[:NK]
        msks_h = packed_h[NK:2 * NK].astype(bool)
        act_h = packed_h[-1].astype(bool)
        emitted = int(msks_h.sum())
        accepted = proposed = 0
        if self._spec:
            accepted = int(packed_h[2 * NK:2 * NK + N].sum())
            # emission row 0 of each round is exactly "active at round
            # start" — each active round made spec_k proposals
            rounds = int(msks_h.reshape(
                N, self.tokens_per_step, -1)[:, 0, :].sum())
            proposed = self.spec_k * rounds
            self.spec_accepted += accepted
            self.spec_proposed += proposed
        # quanta that just compiled don't measure decode speed — feeding
        # them to the tracker skews the admission f-ratio for many cycles
        # (probe unavailable (-1) → record everything: a slightly skewed f
        # beats a tracker frozen at its prior)
        warm = n0 < 0 or _jit_cache_size(self._decode_loop) == n0
        if emitted and warm:
            # `emitted` counts accepted emissions, never rounds — so this
            # is acceptance-scaled *effective* tok/s (the routing signal)
            self.tracker.record("decode", emitted, dt)
        if self.paged:
            self.pos_host += msks_h.sum(axis=0)
        for q in range(NK):
            row = msks_h[q]
            for i in active_slots:
                if row[i]:
                    self.slot_req[i].out.append(int(toks_h[q, i]))
        for i in active_slots:
            if not act_h[i]:
                self.slot_req[i].done = True
                self.slot_req[i] = None
                if self.paged:
                    self._release_slot_pages(i)
        self.cycle_log.append({"admitted": self._last_admitted,
                               "decoded": emitted, "f": self.tracker.f()})
        return StepReport(admitted=self._last_admitted, decoded=emitted,
                          dt=dt, warm=warm, accepted=accepted,
                          proposed=proposed)

    def _admit_pending(self, free: list[int]) -> None:
        """HBB chunking law over token units: the decode quantum is the
        fixed accelerator chunk (S_f = quantum × slots tokens); the prompt-
        token budget admitted this cycle is the adaptive S_c side. Paged
        engines additionally stop at the pool's worst-case page budget
        (admission backpressure instead of a mid-quantum page fault)."""
        r_tokens = sum(len(q.prompt) for q in self.pending)
        budget = cpu_chunk(S_f=self.quantum_tokens * self.max_slots,
                           f=self.tracker.f(), r=r_tokens, n_cores=1)
        take: list[Request] = []
        planned_pages = 0
        while self.pending and len(take) < len(free):
            req = self.pending[0]
            n = len(req.prompt)
            if take and budget < n:            # always admit ≥ 1
                break
            if self.paged:
                W = self._worst_pages(req)
                if not self.alloc.can_commit(planned_pages + W):
                    break                      # pool backpressure
                planned_pages += W
            budget -= n
            take.append(self.pending.pop(0))
        if not take:
            return
        self._last_admitted = len(take)
        groups: dict[int, list[Request]] = {}
        for req in take:
            b = (bucket_len(len(req.prompt), min_bucket=self.min_bucket,
                            max_bucket=self.max_len)
                 if self.pad_safe else len(req.prompt))
            groups.setdefault(b, []).append(req)
        ptoks = 0
        pdt = 0.0
        for Sb in sorted(groups):
            grp = groups[Sb]
            for k0 in range(0, len(grp), self.prefill_batch):
                chunk = grp[k0:k0 + self.prefill_batch]
                dt, warm = self._prefill_group(Sb, chunk, free)
                if warm:                       # skip compile-tainted samples
                    pdt += dt
                    ptoks += sum(len(q.prompt) for q in chunk)
        # device interval only: host-side packing and the first-token fetch
        # used to ride along and skewed the admission f-ratio low
        if ptoks:
            self.tracker.record("prefill", ptoks, pdt)

    def _prefill_group(self, Sb: int, reqs: list[Request],
                       free: list[int]) -> tuple[float, bool]:
        """Prefill + admit one bucket group; returns (device seconds for the
        prefill dispatch + admit scatter, blocked-until-ready; whether the
        interval is compile-free and thus safe to feed the f-tracker)."""
        # fixed batch for padded buckets (one compile per bucket); smallest
        # power-of-2 batch for exact-length (mamba) groups
        P = (self.prefill_batch if self.pad_safe
             else 1 << (len(reqs) - 1).bit_length())
        toks = np.zeros((P, Sb), np.int32)
        pl = np.ones(P, np.int32)
        mn = np.ones(P, np.int32)
        valid = np.zeros(P, bool)
        slots = np.zeros(P, np.int32)
        for j, req in enumerate(reqs):
            toks[j, :len(req.prompt)] = req.prompt
            pl[j] = len(req.prompt)
            mn[j] = req.max_new
            valid[j] = True
            slots[j] = free.pop(0)
        extra = ()
        if self.paged:
            # step() pushes the updated table to device before the next
            # decode quantum; the admit scatter itself reads page_src only
            extra = (jnp.asarray(self._alloc_group_pages(Sb, reqs, slots)),)
        t0 = time.perf_counter()
        p0 = _jit_cache_size(self._prefill_fast)
        a0 = _jit_cache_size(self._admit)
        self._prefill_rng, sub = jax.random.split(self._prefill_rng)
        first, new_cache = self._prefill_fast(self._loop_params,
                                              jnp.asarray(toks),
                                              jnp.asarray(pl), sub)
        (self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
         self.remaining_dev) = self._admit(
            self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
            self.remaining_dev, new_cache, first, jnp.asarray(pl),
            jnp.asarray(mn), jnp.asarray(slots), jnp.asarray(valid), *extra)
        jax.block_until_ready((first, self.tokens_dev))
        dt = time.perf_counter() - t0
        # probe unavailable (-1 sentinel) → treat as warm and record
        warm = (p0 < 0 or a0 < 0
                or (_jit_cache_size(self._prefill_fast) == p0
                    and _jit_cache_size(self._admit) == a0))
        self.prefill_groups += 1
        first_h = _host_fetch(first)           # one sync per admitted group
        for j, req in enumerate(reqs):
            req.out.append(int(first_h[j]))
            if req.max_new <= 1:
                req.done = True                # budget spent at prefill
                free.insert(0, int(slots[j]))
                if self.paged:
                    self._release_slot_pages(int(slots[j]))
            else:
                self.slot_req[int(slots[j])] = req
                if self.paged:
                    self.pos_host[int(slots[j])] = len(req.prompt)
        return dt, warm

    def _alloc_group_pages(self, Sb: int, reqs: list[Request],
                           slots: np.ndarray) -> np.ndarray:
        """Commit each request's worst-case page budget, hand out the pages
        its prompt needs now, and build the pool-page → prefill-row source
        map the paged admit scatter consumes."""
        ps = self.page_size
        Tb = -(-Sb // ps)                      # pages per bucket row
        page_src = np.full(self.num_pages, -1, np.int32)
        for j, req in enumerate(reqs):
            slot = int(slots[j])
            self.alloc.commit(slot, self._worst_pages(req))
            need = -(-len(req.prompt) // ps)
            self.alloc.grow_to(slot, need)
            self._table_dirty = True
            for t in range(need):
                page_src[self.alloc.table[slot, t]] = j * Tb + t
        return page_src

    # ---- reference slow path (pre-fast-path engine, kept for baselines) --
    def _step_legacy(self) -> StepReport:
        free = self.free_slots()
        admitted = 0
        if self.pending and free:
            r = len(self.pending)
            admit = cpu_chunk(S_f=self.max_slots, f=self.tracker.f(), r=r,
                              n_cores=1)
            admit = max(1, min(admit, len(free), r))
            admitted = admit
            t0 = time.perf_counter()
            for _ in range(admit):
                req = self.pending.pop(0)
                slot = self.free_slots()[0]
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, one_cache = self._prefill(self.params, toks)
                self.cache = self._insert(self.cache, one_cache,
                                          jnp.int32(slot))
                nxt = int(jnp.argmax(logits[0]))
                req.out.append(nxt)
                if req.max_new <= 1:           # budget spent at prefill
                    req.done = True            # (stream parity w/ fast path)
                    continue
                self.slot_req[slot] = req
                self.pos[slot] = len(req.prompt)
            self.tracker.record("prefill", admit, time.perf_counter() - t0)

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return StepReport(admitted=admitted)
        toks = np.zeros(self.max_slots, np.int32)
        for i in active:
            toks[i] = self.slot_req[i].out[-1]
        t0 = time.perf_counter()
        n0 = _jit_cache_size(self._decode)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        dt = time.perf_counter() - t0
        # compile-tainted intervals must not reach a throughput tracker
        # (StepReport.warm contract — same probe as the fast path)
        warm = n0 < 0 or _jit_cache_size(self._decode) == n0
        self.tracker.record("decode", len(active), dt)
        for i in active:
            req = self.slot_req[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(req.out) >= req.max_new or int(nxt[i]) == self.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None
        self.cycle_log.append({"admitted": admitted, "decoded": len(active),
                               "f": self.tracker.f()})
        return StepReport(admitted=admitted, decoded=len(active), dt=dt,
                          warm=warm)

    def _guard_limit(self) -> int:
        """Cycle budget proportional to outstanding work: every request
        needs ≲ 1 admission cycle plus max_new/quantum decode cycles; 8× is
        generous slack for admission backpressure and scheduler warm-up."""
        quantum = self.decode_quantum if self.fast else 1
        reqs = self.pending + [r for r in self.slot_req if r is not None]
        tokens = sum(max(1, r.max_new) for r in reqs)
        return 64 + 8 * (len(reqs) + -(-tokens // quantum))

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        guard, limit = 0, self._guard_limit()
        while self.pending or any(s is not None for s in self.slot_req):
            if guard >= limit:
                undone = sum(1 for r in requests if not r.done)
                raise EngineStallError(
                    f"no forward progress after {guard} cycles "
                    f"(limit {limit}): {len(self.pending)} pending, "
                    f"{undone} unfinished requests — engine scheduling bug "
                    f"or pool/slot starvation")
            self.step()
            guard += 1
        return requests


def make_engine(cfg: ModelConfig, ctx: ShardCtx, seed: int = 0,
                **kw) -> Engine:
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    return Engine(cfg, params, ctx, **kw)
