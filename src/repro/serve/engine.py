"""Continuous-batching serving engine.

Slot-based: a fixed decode batch of `max_slots` sequences; finished slots
are refilled by prefilling pending requests and inserting their caches at
the slot index. Admission control follows the paper's scheduling law: the
accelerator class is the fused decode quantum (fixed `S_f`), prefill
admission is the adaptive `S_c` side, driven by the measured
prefill:decode *token* throughput ratio `f` (so a long prompt backlog
can't starve decode, and vice versa).

Fast path (default; DESIGN.md §"Serving fast path"):
  * decode runs `decode_quantum` tokens per dispatch via a jitted
    `lax.scan` with on-device argmax and per-slot done masking — one host
    sync per quantum instead of one per token;
  * the KV cache and (tokens, pos, active, remaining) state vectors stay
    resident on device and are *donated* through the decode loop, so a
    decode step updates the cache in place instead of allocating a new one;
  * prompts are padded to power-of-2 length buckets and prefilled batched
    (fixed batch `prefill_batch`), then inserted with a single gather-based
    scatter — one XLA compile per bucket, one dispatch per admitted group.

`fast=False` keeps the original per-token / per-prompt reference path; the
benchmark (benchmarks/bench_serve.py) and the equivalence tests in
tests/test_serve.py run both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.chunking import cpu_chunk
from repro.core.tracker import ThroughputTracker
from repro.models.model import model_defs
from repro.models.transformer import layer_schedule
from repro.serve.decode import decode_loop_fn, decode_step
from repro.serve.kv_cache import cache_defs
from repro.serve.prefill import bucket_len, prefill
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


def _jit_cache_size(fn) -> int:
    """Compile-count probe: distinct traced signatures of a jitted fn."""
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx, *,
                 max_slots: int = 4, max_len: int = 128, eos_id: int = -1,
                 decode_quantum: int = 8, prefill_batch: int | None = None,
                 min_bucket: int = 16, fast: bool = True):
        assert not cfg.enc_dec, "enc-dec serving uses whisper_decode_step"
        self.cfg, self.params, self.ctx = cfg, params, ctx
        self.max_slots, self.max_len, self.eos_id = max_slots, max_len, eos_id
        self.fast = fast
        self.decode_quantum = max(1, decode_quantum)
        self.prefill_batch = prefill_batch or max_slots
        self.min_bucket = min_bucket
        # padded buckets are only sound when every mixer is attention —
        # a mamba state scan would absorb the pad tokens (DESIGN.md)
        self.pad_safe = all(bc.mixer == "attn"
                            for seg in layer_schedule(cfg)
                            for bc in seg.pattern)
        msize = ctx.axis_size("model")
        self.cache = prm.materialize(
            cache_defs(cfg, max_slots, max_len, msize), jax.random.PRNGKey(0))
        self.pos = np.zeros(max_slots, np.int32)       # legacy-path mirror
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.pending: list[Request] = []
        self.tracker = ThroughputTracker(
            {"decode": "accelerator", "prefill": "core"}, f0=2.0)
        self.cycle_log: list[dict] = []                # per-cycle balance
        self._last_admitted = 0
        # device-resident decode state (fast path)
        self.tokens_dev = jnp.zeros(max_slots, jnp.int32)
        self.pos_dev = jnp.zeros(max_slots, jnp.int32)
        self.active_dev = jnp.zeros(max_slots, bool)
        self.remaining_dev = jnp.zeros(max_slots, jnp.int32)
        # ---- jitted cells -------------------------------------------------
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx))
        self._prefill = jax.jit(
            lambda p, t: prefill(cfg, p, t, ctx, max_len=max_len))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._decode_loop = jax.jit(
            decode_loop_fn(cfg, ctx, num_steps=self.decode_quantum,
                           eos_id=eos_id, max_len=max_len),
            donate_argnums=(1, 2, 3, 4, 5))
        self._prefill_fast = jax.jit(self._prefill_fast_impl)
        self._admit = jax.jit(self._admit_impl,
                              donate_argnums=(0, 1, 2, 3, 4))

    # ---- cache slot insertion (jitted scatter on the batch dim) ----------
    def _insert_impl(self, cache, one_cache, slot):
        # cache leaves are (repeat, batch, …) — batch is axis 1
        def ins(c, o):
            return jax.lax.dynamic_update_slice_in_dim(c, o.astype(c.dtype),
                                                       slot, 1)
        return jax.tree.map(ins, cache, one_cache)

    # ---- fast path: batched prefill + fused admission --------------------
    def _prefill_fast_impl(self, params, toks, prompt_len):
        """(P,Sb) padded prompts → (first greedy token (P,), batched cache).
        Argmax happens on device so admission never ships logits home."""
        logits, cache = prefill(self.cfg, params, toks, self.ctx,
                                max_len=self.max_len, prompt_len=prompt_len)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    def _admit_impl(self, cache, tokens, pos, active, remaining, new_cache,
                    first, prompt_len, max_new, slots, valid):
        """Scatter a prefilled batch into its engine slots in ONE dispatch.

        Formulated as a gather so it stays shape-stable under jit: for each
        engine slot s, pick the (at most one) prefill row targeting s and
        blend it into every cache leaf / state vector.
        """
        S = self.max_slots
        sel = valid[None, :] & (slots[None, :] == jnp.arange(S)[:, None])
        hit = sel.any(axis=1)                  # (S,) slot receives a row?
        idx = jnp.argmax(sel, axis=1)          # (S,) which prefill row

        def ins(c, o):
            g = jnp.take(o, idx, axis=1)       # (repeat, S, …)
            m = hit.reshape((1, S) + (1,) * (c.ndim - 2))
            return jnp.where(m, g.astype(c.dtype), c)

        cache = jax.tree.map(ins, cache, new_cache)
        pl = jnp.take(prompt_len, idx)
        rem = jnp.take(max_new, idx) - 1       # prefill already emitted one
        tokens = jnp.where(hit, jnp.take(first, idx), tokens)
        pos = jnp.where(hit, pl, pos)
        remaining = jnp.where(hit, rem, remaining)
        # pl == max_len-1 still gets one decode step (writes the last cache
        # slot) — matches the legacy path's post-step done check
        active = jnp.where(hit, (rem > 0) & (pl < self.max_len), active)
        return cache, tokens, pos, active, remaining

    def submit(self, req: Request) -> None:
        assert len(req.prompt) < self.max_len, (len(req.prompt), self.max_len)
        self.pending.append(req)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def prefill_compiles(self) -> int:
        """Distinct prefill compiles so far (fast: one per length bucket)."""
        return _jit_cache_size(self._prefill_fast if self.fast
                               else self._prefill)

    # ---- one engine cycle -------------------------------------------------
    def step(self) -> None:
        if not self.fast:
            self._step_legacy()
            return
        self._last_admitted = 0
        free = self.free_slots()
        if self.pending and free:
            self._admit_pending(free)
        active_slots = [i for i, r in enumerate(self.slot_req)
                        if r is not None]
        if not active_slots:
            if self._last_admitted:   # everything finished at prefill —
                self.cycle_log.append({"admitted": self._last_admitted,
                                       "decoded": 0,
                                       "f": self.tracker.f()})
            return
        t0 = time.perf_counter()
        carry, toks, msks = self._decode_loop(
            self.params, self.cache, self.tokens_dev, self.pos_dev,
            self.active_dev, self.remaining_dev)
        (self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
         self.remaining_dev) = carry
        toks_h = np.asarray(toks)              # ONE host sync per quantum
        msks_h = np.asarray(msks)
        act_h = np.asarray(self.active_dev)
        dt = time.perf_counter() - t0
        emitted = int(msks_h.sum())
        if emitted:
            self.tracker.record("decode", emitted, dt)
        for q in range(self.decode_quantum):
            row = msks_h[q]
            for i in active_slots:
                if row[i]:
                    self.slot_req[i].out.append(int(toks_h[q, i]))
        for i in active_slots:
            if not act_h[i]:
                self.slot_req[i].done = True
                self.slot_req[i] = None
        self.cycle_log.append({"admitted": self._last_admitted,
                               "decoded": emitted, "f": self.tracker.f()})

    def _admit_pending(self, free: list[int]) -> None:
        """HBB chunking law over token units: the decode quantum is the
        fixed accelerator chunk (S_f = quantum × slots tokens); the prompt-
        token budget admitted this cycle is the adaptive S_c side."""
        r_tokens = sum(len(q.prompt) for q in self.pending)
        budget = cpu_chunk(S_f=self.decode_quantum * self.max_slots,
                           f=self.tracker.f(), r=r_tokens, n_cores=1)
        take: list[Request] = []
        while self.pending and len(take) < len(free):
            n = len(self.pending[0].prompt)
            if take and budget < n:            # always admit ≥ 1
                break
            budget -= n
            take.append(self.pending.pop(0))
        if not take:
            return
        self._last_admitted = len(take)
        groups: dict[int, list[Request]] = {}
        for req in take:
            b = (bucket_len(len(req.prompt), min_bucket=self.min_bucket,
                            max_bucket=self.max_len)
                 if self.pad_safe else len(req.prompt))
            groups.setdefault(b, []).append(req)
        t0 = time.perf_counter()
        ptoks = 0
        for Sb in sorted(groups):
            grp = groups[Sb]
            for k0 in range(0, len(grp), self.prefill_batch):
                chunk = grp[k0:k0 + self.prefill_batch]
                self._prefill_group(Sb, chunk, free)
                ptoks += sum(len(q.prompt) for q in chunk)
        self.tracker.record("prefill", ptoks, time.perf_counter() - t0)

    def _prefill_group(self, Sb: int, reqs: list[Request],
                       free: list[int]) -> None:
        # fixed batch for padded buckets (one compile per bucket); smallest
        # power-of-2 batch for exact-length (mamba) groups
        P = (self.prefill_batch if self.pad_safe
             else 1 << (len(reqs) - 1).bit_length())
        toks = np.zeros((P, Sb), np.int32)
        pl = np.ones(P, np.int32)
        mn = np.ones(P, np.int32)
        valid = np.zeros(P, bool)
        slots = np.zeros(P, np.int32)
        for j, req in enumerate(reqs):
            toks[j, :len(req.prompt)] = req.prompt
            pl[j] = len(req.prompt)
            mn[j] = req.max_new
            valid[j] = True
            slots[j] = free.pop(0)
        first, new_cache = self._prefill_fast(self.params, jnp.asarray(toks),
                                              jnp.asarray(pl))
        (self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
         self.remaining_dev) = self._admit(
            self.cache, self.tokens_dev, self.pos_dev, self.active_dev,
            self.remaining_dev, new_cache, first, jnp.asarray(pl),
            jnp.asarray(mn), jnp.asarray(slots), jnp.asarray(valid))
        first_h = np.asarray(first)            # one sync per admitted group
        for j, req in enumerate(reqs):
            req.out.append(int(first_h[j]))
            if req.max_new <= 1:
                req.done = True                # budget spent at prefill
                free.insert(0, int(slots[j]))
            else:
                self.slot_req[int(slots[j])] = req

    # ---- reference slow path (pre-fast-path engine, kept for baselines) --
    def _step_legacy(self) -> None:
        free = self.free_slots()
        admitted = 0
        if self.pending and free:
            r = len(self.pending)
            admit = cpu_chunk(S_f=self.max_slots, f=self.tracker.f(), r=r,
                              n_cores=1)
            admit = max(1, min(admit, len(free), r))
            admitted = admit
            t0 = time.perf_counter()
            for _ in range(admit):
                req = self.pending.pop(0)
                slot = self.free_slots()[0]
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, one_cache = self._prefill(self.params, toks)
                self.cache = self._insert(self.cache, one_cache,
                                          jnp.int32(slot))
                nxt = int(jnp.argmax(logits[0]))
                req.out.append(nxt)
                if req.max_new <= 1:           # budget spent at prefill
                    req.done = True            # (stream parity w/ fast path)
                    continue
                self.slot_req[slot] = req
                self.pos[slot] = len(req.prompt)
            self.tracker.record("prefill", admit, time.perf_counter() - t0)

        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros(self.max_slots, np.int32)
        for i in active:
            toks[i] = self.slot_req[i].out[-1]
        t0 = time.perf_counter()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.tracker.record("decode", len(active), time.perf_counter() - t0)
        for i in active:
            req = self.slot_req[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(req.out) >= req.max_new or int(nxt[i]) == self.eos_id
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None
        self.cycle_log.append({"admitted": admitted, "decoded": len(active),
                               "f": self.tracker.f()})

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        guard = 0
        while (self.pending or any(self.slot_req)) and guard < 10_000:
            self.step()
            guard += 1
        return requests


def make_engine(cfg: ModelConfig, ctx: ShardCtx, seed: int = 0,
                **kw) -> Engine:
    params = prm.materialize(model_defs(cfg), jax.random.PRNGKey(seed))
    return Engine(cfg, params, ctx, **kw)
