"""Model / shape configuration system.

One :class:`ModelConfig` describes any of the 10 assigned architectures
(dense / MoE / SSM / hybrid / VLM-backbone / enc-dec-audio). Each arch file
under ``repro/configs`` registers a full-size config (used only abstractly by
the dry-run) and every config has a family-preserving ``smoke()`` reduction
that runs a real step on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert ffn hidden size
    n_shared: int = 0             # shared (always-on) experts, DeepSeek-style
    period: int = 1               # MoE layer every `period` layers …
    offset: int = 0               # … at slot `offset` within the period
    first_dense: int = 0          # first N layers use a dense FFN instead
    dense_d_ff: int = 0           # hidden size of those dense layers
    capacity_factor: float = 1.25
    aux_weight: float = 1e-3


@dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64            # decoupled rope key dim (shared across heads)
    nope_dim: int = 128           # per-head no-pos dims
    v_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 only
    version: int = 2              # 1 (selective scan) | 2 (SSD)
    attn_period: int = 0          # hybrid: one attention layer every N (jamba: 8)
    attn_offset: int = 0          # slot of the attention layer within the period
    chunk: int = 256              # SSD / selective-scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"           # swiglu | geglu | relu2 | gelu
    norm_eps: float = 1e-5
    use_post_norm: bool = False   # gemma2 sandwich norms
    rope_theta: float = 10_000.0
    use_rope: bool = True         # jamba/whisper: no rope
    attn_softcap: float = 0.0     # gemma2: 50
    final_softcap: float = 0.0    # gemma2: 30
    sliding_window: int = 0       # 0 = full attention
    local_global_period: int = 0  # gemma2: 2 → alternate sliding/full
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"        # none | vision | audio  (stub embeddings)
    frontend_tokens: int = 0      # vlm: patch tokens prepended to the text
    frontend_dim: int = 0         # stub embedding dim (pre-projection)
    max_decoder_len: int = 448    # whisper decoder context
    tie_embeddings: bool = False
    embed_scale: bool = False     # gemma: multiply embeddings by sqrt(d)
    attn_chunk: int = 512         # online-softmax KV/Q chunk (XLA path)
    param_dtype: str = "bfloat16"
    source: str = ""              # provenance note

    # -- derived ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def is_attn_layer(self, i: int) -> bool:
        if self.ssm is None:
            return True
        if self.ssm.attn_period == 0:
            return False                      # pure SSM
        return i % self.ssm.attn_period == self.ssm.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_dense:
            return False
        return i % self.moe.period == self.moe.offset

    def window_for_layer(self, i: int) -> int:
        """0 = full attention; >0 = sliding window size."""
        if self.sliding_window and self.local_global_period:
            return self.sliding_window if i % self.local_global_period == 0 else 0
        return self.sliding_window

    def sub_quadratic(self) -> bool:
        """True iff every mixer is SSM or bounded-window attention."""
        for i in range(self.n_layers):
            if self.is_attn_layer(i) and self.window_for_layer(i) == 0:
                # hybrid archs keep a few full-attn layers: their 512k KV is
                # seq-sharded (flash-decoding), which we accept as runnable.
                if self.family in ("hybrid",):
                    continue
                return False
        return True


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs, with the DESIGN.md §4 skip reasons."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid") or (cfg.sliding_window and not cfg.local_global_period):
            return True, ""
        return False, ("long_500k skipped: pure full attention (quadratic); "
                       "see DESIGN.md §4")
    if shape.kind == "decode" and cfg.family == "audio":
        # enc-dec decode = decoder step against a cross-KV of `seq_len` frames
        return True, ""
    return True, ""


# ---------------------------------------------------------------- registry
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


# ---------------------------------------------------------- smoke reduction
def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction that runs a real CPU step in <~seconds."""
    period = 1
    if cfg.local_global_period:
        period = max(period, cfg.local_global_period)
    if cfg.ssm and cfg.ssm.attn_period:
        period = max(period, cfg.ssm.attn_period)
    if cfg.moe:
        period = max(period, cfg.moe.period)
        period = max(period, cfg.moe.first_dense + cfg.moe.period)
    n_layers = max(2, period)

    moe = None
    if cfg.moe:
        moe = replace(cfg.moe, n_experts=min(8, cfg.moe.n_experts),
                      top_k=min(2, cfg.moe.top_k), d_expert=64,
                      n_shared=min(1, cfg.moe.n_shared),
                      dense_d_ff=128 if cfg.moe.dense_d_ff else 0)
    mla = None
    if cfg.mla:
        mla = MLACfg(kv_lora=32, q_lora=48, rope_dim=8, nope_dim=16, v_dim=16)
    ssm = None
    if cfg.ssm:
        ssm = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)

    head_dim = 16 if cfg.mla is None else 16
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads < cfg.n_heads else n_heads
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        n_enc_layers=2 if cfg.enc_dec else 0,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        moe=moe, mla=mla, ssm=ssm,
        sliding_window=32 if cfg.sliding_window else 0,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        max_decoder_len=16 if cfg.enc_dec else cfg.max_decoder_len,
        attn_chunk=16,
    )
