"""Jamba v0.1 52B (arXiv:2403.19887; hf ai21labs/Jamba-v0.1).

Hybrid Mamba-1 + attention, 1:7 attn:mamba interleave (attention at slot 4
of each 8-layer block), MoE (16 experts, top-2) on every 2nd layer (odd
slots), no positional embeddings (attention relies on mamba for position).
"""
from repro.configs.base import MoECfg, ModelConfig, SSMCfg, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab=65_536,
    act="swiglu",
    use_rope=False,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14_336, n_shared=0,
               period=2, offset=1, capacity_factor=1.25, aux_weight=1e-2),
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, version=1,
               attn_period=8, attn_offset=4),
    source="arXiv:2403.19887; hf",
))
