"""Whisper large-v3 (arXiv:2212.04356; hf openai/whisper-large-v3).

Encoder-decoder, 32+32 layers, d 1280, 20 MHA heads, ffn 5120, vocab 51866,
GELU, learned/sinusoidal positions (no rope). The conv1d mel frontend is a
STUB per the assignment: ``input_specs()`` provides post-conv frame
embeddings (B, frames, 1280). Shape semantics (DESIGN.md §4): seq_len is the
encoder frame count; decode cells run one decoder step against a cross-KV of
that length with a self-KV of max_decoder_len=448.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51_866,
    act="gelu",
    use_rope=False,
    enc_dec=True,
    tie_embeddings=True,
    frontend="audio",
    frontend_dim=1280,
    max_decoder_len=448,
    source="arXiv:2212.04356; unverified",
))
