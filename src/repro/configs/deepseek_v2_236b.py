"""DeepSeek-V2 236B (arXiv:2405.04434; hf deepseek-ai/DeepSeek-V2).

MoE with Multi-head Latent Attention: kv_lora_rank=512, q_lora_rank=1536,
decoupled rope dim 64, nope dim 128, v dim 128. 160 routed experts (top-6)
+ 2 shared experts, expert hidden 1536; the first layer uses a dense FFN of
hidden 12288 (per the paper / HF config `first_k_dense_replace=1`).
"""
from repro.configs.base import MLACfg, MoECfg, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent KV shared; logical heads = 128
    head_dim=192,            # nope 128 + rope 64 (qk); v_dim 128
    d_ff=1536,               # routed-expert hidden (assignment spec)
    vocab=102_400,
    act="swiglu",
    rope_theta=10_000.0,
    moe=MoECfg(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
               period=1, offset=0, first_dense=1, dense_d_ff=12_288,
               capacity_factor=1.25, aux_weight=3e-3),
    mla=MLACfg(kv_lora=512, q_lora=1536, rope_dim=64, nope_dim=128, v_dim=128),
    source="arXiv:2405.04434; hf",
))
