"""Phi-3.5-MoE 42B (A6.6B) — hf:microsoft/Phi-3.5-MoE-instruct.

16 experts, top-2 routing, GQA with 8 KV heads, expert hidden 6400.
"""
from repro.configs.base import MoECfg, ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32_064,
    act="swiglu",
    rope_theta=10_000.0,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=6400, n_shared=0,
               period=1, offset=0, capacity_factor=1.25, aux_weight=1e-2),
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
))
