"""Mamba2 130M (arXiv:2405.21060 — state-space duality / SSD).

Attention-free: 24 pure SSD mixer blocks (no FFN, d_ff=0), d_inner=1536
(expand 2), ssm_state=128, head_dim 64 → 24 SSD heads, conv kernel 4.
"""
from repro.configs.base import ModelConfig, SSMCfg, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50_280,
    use_rope=False,
    ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, version=2),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
