"""H2O-Danube 1.8B (arXiv:2401.16818; hf h2oai/h2o-danube-1.8b-base).

Llama architecture + Mistral-style sliding-window attention (4096) on every
layer → bounded KV ⇒ eligible for the long_500k decode cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32_000,
    act="swiglu",
    rope_theta=10_000.0,
    sliding_window=4096,
    source="arXiv:2401.16818; hf",
))
