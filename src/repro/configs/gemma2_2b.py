"""Gemma 2 2B (arXiv:2408.00118; hf google/gemma-2-2b).

Alternating local (window 4096) / global attention, GeGLU, attention logit
softcap 50, final logit softcap 30, sandwich (pre+post) RMSNorms, tied
embeddings scaled by sqrt(d_model), head_dim 256.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    act="geglu",
    use_post_norm=True,
    rope_theta=10_000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118; hf",
))
