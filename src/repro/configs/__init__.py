"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (  # noqa: F401
    MLACfg, MoECfg, ModelConfig, SHAPES, ShapeSpec, SSMCfg,
    all_configs, cell_supported, get_config, register, smoke_config,
)

# one module per assigned architecture (registration side effect)
from repro.configs import (  # noqa: F401
    deepseek_v2_236b,
    phi35_moe_42b,
    gemma2_2b,
    h2o_danube_18b,
    nemotron4_15b,
    mistral_nemo_12b,
    mamba2_130m,
    jamba_v01_52b,
    internvl2_26b,
    whisper_large_v3,
)


def arch_names():
    return sorted(all_configs())
