"""The paper's own benchmark configuration (HIP3ES 2018, Tables 1/2, §4-5).

GEMM with the Fig. 4 tiling, 1M-element matrices (1024×1024) for the main
experiment and 16M (4096×4096) for the scaling study. "Buffered columns"
(32 on Zynq Z7020, 128 on ZynqUS+ ZU9) is the on-chip-capacity knob — the
TPU analogue is the Pallas BlockSpec tile swept in benchmarks/bench_gemm.py.

Platform constants (Table 1) are kept for the energy model of Fig. 6.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class Platform:
    name: str
    n_cpu_cores: int
    n_fpga_units: int
    cpu_freq_mhz: float
    power_budget_w: float        # measured peak in the paper (§5)
    rel_fpga_speed: float        # calibrated f (FPGA CU vs one CPU core)
    buffered_columns: int        # Table 2 capacity knob


# Paper Table 1 + §5 measurements. rel_fpga_speed is calibrated so the
# heterogeneous time reduction ncc/(f·nfc + ncc) lands in the paper's §6
# 25–50 % band: Zynq 2/(4+2) = 33 %, ZynqUS+ 4/(2.5·4+4) = 28.6 %.
ZYNQ_7020 = Platform("zynq-z7020", n_cpu_cores=2, n_fpga_units=1,
                     cpu_freq_mhz=600.0, power_budget_w=0.8,
                     rel_fpga_speed=4.0, buffered_columns=32)
ZYNQ_ULTRA_ZU9 = Platform("zynq-ultrascale-zu9", n_cpu_cores=4, n_fpga_units=4,
                          cpu_freq_mhz=1400.0, power_budget_w=4.2,
                          rel_fpga_speed=2.5, buffered_columns=128)

PLATFORMS = {p.name: p for p in (ZYNQ_7020, ZYNQ_ULTRA_ZU9)}

# Main experiment: 1M elements; scaling study: 16M elements (paper §5).
GEMM_N_MAIN = 1024
GEMM_N_SCALING = 4096
# FPGA chunk sizes swept on the X axis of Fig. 5 (rows of C per chunk).
FPGA_CHUNK_SWEEP = (8, 16, 32, 64, 128, 256)
