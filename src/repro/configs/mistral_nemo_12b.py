"""Mistral-NeMo 12B (hf:mistralai/Mistral-Nemo-Base-2407).

128k context (rope theta 1e6), head_dim 128 (explicit, ≠ d_model/n_heads).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab=131_072,
    act="swiglu",
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
))
