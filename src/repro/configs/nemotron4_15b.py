"""Nemotron-4 15B (arXiv:2402.16819).

GQA (48 q / 8 kv heads), squared-ReLU MLP (no gating), vocab 256k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab=256_000,
    act="relu2",
    rope_theta=10_000.0,
    source="arXiv:2402.16819; unverified",
))
