"""InternVL2 26B (arXiv:2404.16821; hf OpenGVLab/InternVL2-26B).

InternLM2-20B language backbone (48L / d 6144 / 48H GQA kv 8 / ffn 16384 /
vocab 92553). The InternViT-6B vision frontend is a STUB per the assignment:
``input_specs()`` provides 256 precomputed patch embeddings per image
(post pixel-shuffle, pre-MLP-projector, dim 3200) that the model projects
and prepends to the text tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab=92_553,
    act="swiglu",
    rope_theta=10_000.0,
    frontend="vision",
    frontend_tokens=256,
    frontend_dim=3200,
    source="arXiv:2404.16821; hf",
))
