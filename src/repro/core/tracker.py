"""Per-resource throughput tracking → the paper's online `f` factor.

Stage S2 of the HBB pipeline records (chunk_size, service_time) for every
completed chunk; `f` is the EWMA throughput of the accelerator class divided
by the mean EWMA throughput of the CPU-core class (§3.1: "this time is used
to update the relative speed of the FC w.r.t. a CC").
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field


@dataclass
class ResourceStats:
    kind: str                      # "accelerator" | "core"
    ewma_thr: float = 0.0          # iterations / second
    n_chunks: int = 0
    iters_done: int = 0
    busy_time: float = 0.0

    def record(self, chunk: int, dt: float, alpha: float) -> None:
        thr = chunk / max(dt, 1e-12)
        self.ewma_thr = thr if self.n_chunks == 0 else (
            alpha * thr + (1 - alpha) * self.ewma_thr)
        self.n_chunks += 1
        self.iters_done += chunk
        self.busy_time += dt


class ThroughputTracker:
    """Thread-safe f-factor tracker shared by the dispatch pipeline."""

    def __init__(self, resources: dict[str, str], f0: float = 8.0,
                 alpha: float = 0.5):
        self.stats = {n: ResourceStats(kind=k) for n, k in resources.items()}
        self._f0 = f0
        self._alpha = alpha
        self._lock = threading.Lock()

    def record(self, name: str, chunk: int, dt: float) -> None:
        with self._lock:
            self.stats[name].record(chunk, dt, self._alpha)

    def f(self) -> float:
        """Relative accelerator speed; falls back to the prior until both
        classes have at least one measurement."""
        with self._lock:
            acc = [s.ewma_thr for s in self.stats.values()
                   if s.kind == "accelerator" and s.n_chunks]
            cor = [s.ewma_thr for s in self.stats.values()
                   if s.kind == "core" and s.n_chunks]
            if not acc or not cor or min(cor) <= 0:
                return self._f0
            return max(1e-3, (sum(acc) / len(acc)) / (sum(cor) / len(cor)))

    def throughput(self, name: str) -> float:
        with self._lock:
            return self.stats[name].ewma_thr

    def snapshot(self) -> dict[str, ResourceStats]:
        with self._lock:
            return {n: ResourceStats(s.kind, s.ewma_thr, s.n_chunks,
                                     s.iters_done, s.busy_time)
                    for n, s in self.stats.items()}
