"""Heterogeneous global-batch partitioner (beyond-paper integration).

Training analogue of HBB's ``parallel_for``: the iteration space is the
global batch; resources are *device tiers* (sub-meshes of unequal measured
throughput — mixed pod generations, or degraded nodes). Each step the batch
splits per the equal-service-time operand of the paper's law
(``n_t ∝ f_t``, quantised to each tier's device count); per-step times feed
the StragglerMonitor, whose updated f vector re-partitions the next step —
the paper's online `f` loop at fleet scale.

Gradients are combined host-side with sample-count weights, so the update
is identical to an even split (invariant tested in
tests/test_partitioner.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.core.chunking import proportional_split
from repro.core.straggler import StragglerMonitor


@dataclass
class Tier:
    """A homogeneous group of devices acting as one HBB resource."""
    name: str
    devices: list[Any]
    grad_fn: Callable[..., Any]       # (params, batch_slice) → (grads, metrics)
    slowdown: float = 1.0             # test hook: simulated degradation


@dataclass
class HeterogeneousBatchPartitioner:
    tiers: list[Tier]
    quantum: int = 1                  # per-tier batch must be a multiple
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    warmup_obs: int = 1               # skip first N timings per tier (jit
    _seen: dict = field(default_factory=dict)  # compile time would skew f)

    def split(self, global_batch: int) -> list[int]:
        speeds = self.monitor.relative_speeds()
        spd = [max(speeds.get(t.name, 1.0), 1e-3) for t in self.tiers
               if t.name not in self.monitor.excluded()]
        names = [t.name for t in self.tiers
                 if t.name not in self.monitor.excluded()]
        parts = proportional_split(global_batch, spd, self.quantum)
        out = []
        i = 0
        for t in self.tiers:
            out.append(parts[names.index(t.name)] if t.name in names else 0)
            i += 1
        return out

    def step(self, params, batch) -> tuple[Any, dict]:
        """batch: host arrays dict with leading dim = global_batch. Runs each
        tier on its slice, records service times, returns weighted-mean grads.
        """
        gb = len(jax.tree.leaves(batch)[0])
        parts = self.split(gb)
        grads, counts = [], []
        offset = 0
        for t, n in zip(self.tiers, parts):
            if n == 0:
                continue
            sl = jax.tree.map(lambda x: x[offset:offset + n], batch)
            offset += n
            t0 = time.perf_counter()
            g, _ = t.grad_fn(params, sl)
            g = jax.block_until_ready(g)
            dt = time.perf_counter() - t0
            if t.slowdown > 1.0:
                time.sleep(dt * (t.slowdown - 1.0))
                dt *= t.slowdown
            self._seen[t.name] = self._seen.get(t.name, 0) + 1
            if self._seen[t.name] > self.warmup_obs:
                self.monitor.observe(t.name, n, dt)
            grads.append(g)
            counts.append(n)
        total = sum(counts)
        weights = [c / total for c in counts]
        mean = jax.tree.map(
            lambda *gs: sum(w * g for w, g in zip(weights, gs)), *grads)
        info = {"parts": parts,
                "speeds": self.monitor.relative_speeds(),
                "stragglers": self.monitor.stragglers(),
                "excluded": self.monitor.excluded()}
        return mean, info
