"""Energy model for the Fig. 6 reproduction (no PMBUS rails here).

E = P_static·T_wall + Σ_r P_r·busy_r  — per-class active power plus a
platform static floor, calibrated to the paper's §5 measurements (Zynq peak
0.8 W, ZynqUS+ 4.2 W). The paper's claim under test: heterogeneous configs
are ~energy-neutral because extra CPU power is offset by shorter runtime.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hbb import RunReport


@dataclass(frozen=True)
class PowerModel:
    p_static: float          # W, always-on
    p_core: float            # W per active CPU core
    p_accel: float           # W per active accelerator unit


# Calibrated to the paper's measured peak powers (Zynq 0.8 W, ZynqUS+
# 4.2 W, §5) with the static/active split chosen so the §6 energy-
# neutrality holds at the §6 time reductions: Zynq 0.25+0.283+2·0.133 ≈ 0.8,
# ZynqUS+ 1.4+4·0.4+4·0.3 = 4.2.
POWER_MODELS = {
    "zynq-z7020": PowerModel(p_static=0.25, p_core=0.133, p_accel=0.283),
    "zynq-ultrascale-zu9": PowerModel(p_static=1.4, p_core=0.30, p_accel=0.40),
    # TPU v5e tier model for the beyond-paper partitioner experiments.
    "tpu-v5e": PowerModel(p_static=60.0, p_core=0.0, p_accel=170.0),
}


def run_energy(report: RunReport, kinds: dict[str, str],
               pm: PowerModel) -> tuple[float, float]:
    """→ (energy_J, mean_power_W) for one parallel_for execution."""
    e = pm.p_static * report.wall_time
    for name, kind in kinds.items():
        p = pm.p_accel if kind == "accelerator" else pm.p_core
        e += p * report.busy_time(name)
    return e, e / max(report.wall_time, 1e-12)
