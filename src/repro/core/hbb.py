"""HBB — Heterogeneous Building Blocks (the paper's §3 library), in Python.

Faithful port of the paper's API surface:

    body = Body()                      # operatorCPU / operatorFPGA
    hs = Dynamic.get_instance(params)  # Fig. 2 line 8
    hs.parallel_for(begin, end, body)  # Fig. 2 line 10

The engine is the paper's two-stage pipeline (Fig. 1): stage S1 partitions
the remaining iteration space and dispatches a chunk to a free resource
(token-limited, one token per resource); stage S2 records the chunk's
service time and updates the relative-speed factor ``f`` via
:class:`~repro.core.tracker.ThroughputTracker`. Chunk sizes follow
:mod:`repro.core.chunking` — fixed ``S_f`` for accelerator-class resources,
the adaptive §3.2 law for core-class resources.

Resources are *device tiers* here (DESIGN.md §2): a jitted TPU step fn, a
host-CPU worker, or a calibrated simulator — anything with a
``(begin, end) → None`` body.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.chunking import accelerator_chunk, cpu_chunk
from repro.core.tracker import ThroughputTracker


@dataclass
class Params:
    """Command-line-style scheduler parameters (paper Fig. 2 / §3.1)."""
    num_cpu_tokens: int = 2        # <num_cpu_t>  — CC count
    num_fpga_tokens: int = 1       # <num_fpga_t> — 0 disables the accelerator
    fpga_chunk: int = 64           # <fpga_chunksize> — S_f
    f0: float = 8.0                # initial relative-speed prior
    min_cpu_chunk: int = 1
    scheduler: str = "dynamic"     # dynamic | static | oracle


class Body:
    """User kernel: same iteration body for both device classes (§3.1)."""

    def operatorCPU(self, begin: int, end: int) -> None:  # noqa: N802 (paper API)
        raise NotImplementedError

    def operatorFPGA(self, begin: int, end: int) -> None:  # noqa: N802
        raise NotImplementedError


@dataclass
class Resource:
    name: str
    kind: str                          # "accelerator" | "core"
    run: Callable[[int, int], None]    # bound to Body.operator*


@dataclass
class ChunkRecord:
    resource: str
    begin: int
    end: int
    t_start: float
    t_end: float


@dataclass
class RunReport:
    records: list[ChunkRecord] = field(default_factory=list)
    wall_time: float = 0.0
    f_final: float = 0.0

    def iters_by_kind(self, resources: dict[str, str]) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            k = resources[r.resource]
            out[k] = out.get(k, 0) + (r.end - r.begin)
        return out

    def busy_time(self, name: str) -> float:
        return sum(r.t_end - r.t_start for r in self.records
                   if r.resource == name)


class Dynamic:
    """The paper's dynamic heterogeneous scheduler (singleton per Params)."""

    _instance: Optional["Dynamic"] = None

    def __init__(self, params: Params):
        self.params = params

    @classmethod
    def get_instance(cls, params: Params) -> "Dynamic":
        if cls._instance is None or cls._instance.params != params:
            cls._instance = cls(params)
        return cls._instance

    # -- public API --------------------------------------------------------
    def parallel_for(self, begin: int, end: int, body: Body,
                     resources: Optional[list[Resource]] = None) -> RunReport:
        resources = resources or self._default_resources(body)
        if not resources:
            raise ValueError("no resources enabled")
        if self.params.scheduler == "dynamic":
            return self._run_dynamic(begin, end, resources)
        if self.params.scheduler == "static":
            return self._run_static(begin, end, resources)
        if self.params.scheduler == "oracle":
            return self._run_static(begin, end, resources, use_f=True)
        raise ValueError(self.params.scheduler)

    # -- resource construction ---------------------------------------------
    def _default_resources(self, body: Body) -> list[Resource]:
        res = []
        for i in range(self.params.num_fpga_tokens):
            res.append(Resource(f"FC{i}", "accelerator", body.operatorFPGA))
        for i in range(self.params.num_cpu_tokens):
            res.append(Resource(f"CC{i}", "core", body.operatorCPU))
        return res

    # -- dynamic engine: S1 dispatch / S2 accounting ------------------------
    def _run_dynamic(self, begin: int, end: int,
                     resources: list[Resource]) -> RunReport:
        p = self.params
        n_cores = sum(1 for r in resources if r.kind == "core")
        tracker = ThroughputTracker({r.name: r.kind for r in resources},
                                    f0=p.f0)
        report = RunReport()
        lock = threading.Lock()        # guards `next_iter` (the white region)
        next_iter = begin
        t0 = time.perf_counter()

        def s1_take(kind: str) -> tuple[int, int]:
            """Stage S1: claim the next chunk for a resource class."""
            nonlocal next_iter
            with lock:
                r = end - next_iter
                if r <= 0:
                    return (0, 0)
                if kind == "accelerator":
                    c = accelerator_chunk(p.fpga_chunk, r)
                else:
                    c = cpu_chunk(p.fpga_chunk, tracker.f(), r, max(n_cores, 1),
                                  p.min_cpu_chunk)
                b = next_iter
                next_iter += c
                return (b, b + c)

        def worker(res: Resource) -> None:
            while True:
                b, e = s1_take(res.kind)
                if e <= b:
                    return
                ts = time.perf_counter()
                res.run(b, e)
                te = time.perf_counter()
                tracker.record(res.name, e - b, te - ts)   # stage S2
                with lock:
                    report.records.append(
                        ChunkRecord(res.name, b, e, ts - t0, te - t0))

        threads = [threading.Thread(target=worker, args=(r,), daemon=True)
                   for r in resources]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        report.wall_time = time.perf_counter() - t0
        report.f_final = tracker.f()
        return report

    # -- static / oracle baselines (paper comparison points) ----------------
    def _run_static(self, begin: int, end: int, resources: list[Resource],
                    use_f: bool = False) -> RunReport:
        from repro.core.chunking import proportional_split
        p = self.params
        speeds = [(p.f0 if use_f else 1.0) if r.kind == "accelerator" else 1.0
                  for r in resources]
        split = proportional_split(end - begin, speeds)
        report = RunReport()
        t0 = time.perf_counter()
        bounds = []
        b = begin
        for c in split:
            bounds.append((b, b + c))
            b += c

        def worker(res: Resource, lo: int, hi: int) -> None:
            if hi <= lo:
                return
            ts = time.perf_counter()
            res.run(lo, hi)
            te = time.perf_counter()
            report.records.append(ChunkRecord(res.name, lo, hi, ts - t0,
                                              te - t0))

        threads = [threading.Thread(target=worker, args=(r, lo, hi),
                                    daemon=True)
                   for r, (lo, hi) in zip(resources, bounds)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        report.wall_time = time.perf_counter() - t0
        report.f_final = p.f0
        return report
