"""The paper's §3.2 chunk-size law, as pure functions (property-tested).

    S_c = min( S_f / f ,  r / (f + nCores) )

``S_f``  — fixed accelerator chunk (OpenMP-dynamic for the fast device)
``f``    — measured relative speed of the accelerator w.r.t. one CPU core
``r``    — remaining iterations
The first operand equalises per-chunk service time across device classes;
the second is guided self-scheduling [Rudolph & Polychronopoulos '89] so the
tail drains with bounded imbalance.
"""
from __future__ import annotations


def cpu_chunk(S_f: float, f: float, r: int, n_cores: int,
              min_chunk: int = 1) -> int:
    """Paper Eq. (§3.2). Returns an integer chunk ≥ min_chunk (capped at r)."""
    if r <= 0:
        return 0
    f = max(f, 1e-9)
    sc = min(S_f / f, r / (f + n_cores))
    return max(min_chunk, min(int(sc), r)) if sc >= 1 else min(min_chunk, r)


def accelerator_chunk(S_f: int, r: int) -> int:
    """OpenMP-dynamic: fixed S_f, capped by the remaining iterations."""
    return max(0, min(S_f, r))


def proportional_split(total: int, speeds, quantum: int = 1) -> list[int]:
    """Equal-service-time split of `total` across resources with relative
    speeds `speeds`, rounded to `quantum` (largest-remainder). Used by the
    heterogeneous batch partitioner at steady state."""
    s = sum(speeds)
    assert s > 0 and total % quantum == 0, (speeds, total, quantum)
    units = total // quantum
    raw = [units * v / s for v in speeds]
    base = [int(x) for x in raw]
    rem = units - sum(base)
    order = sorted(range(len(raw)), key=lambda i: raw[i] - base[i],
                   reverse=True)
    for i in order[:rem]:
        base[i] += 1
    return [b * quantum for b in base]
