"""Straggler detection & mitigation — the paper's `f` tracker as fleet health.

A tier whose EWMA throughput drifts below ``beta ×`` the median of its class
is a *straggler*: its chunks shrink automatically (the §3.2 law divides by a
smaller f), and after ``patience`` consecutive flags the tier is marked for
exclusion → the training loop triggers an elastic re-mesh
(:mod:`repro.train.elastic`) + restart from the last checkpoint.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field


@dataclass
class TierHealth:
    ewma_thr: float = 0.0
    n_obs: int = 0
    flags: int = 0
    excluded: bool = False


@dataclass
class StragglerMonitor:
    beta: float = 0.5              # straggler iff thr < beta · median(peers)
    patience: int = 3              # consecutive flags before exclusion
    alpha: float = 0.5             # EWMA
    tiers: dict[str, TierHealth] = field(default_factory=dict)

    def observe(self, tier: str, items: int, dt: float) -> None:
        h = self.tiers.setdefault(tier, TierHealth())
        thr = items / max(dt, 1e-12)
        h.ewma_thr = thr if h.n_obs == 0 else (
            self.alpha * thr + (1 - self.alpha) * h.ewma_thr)
        h.n_obs += 1
        self._update_flags()

    def _update_flags(self) -> None:
        active = {n: h for n, h in self.tiers.items()
                  if not h.excluded and h.n_obs > 0}
        if len(active) < 2:
            return
        med = statistics.median(h.ewma_thr for h in active.values())
        for h in active.values():
            if h.ewma_thr < self.beta * med:
                h.flags += 1
                if h.flags >= self.patience:
                    h.excluded = True
            else:
                h.flags = 0

    def stragglers(self) -> list[str]:
        return [n for n, h in self.tiers.items()
                if h.flags > 0 and not h.excluded]

    def excluded(self) -> list[str]:
        return [n for n, h in self.tiers.items() if h.excluded]

    def relative_speeds(self) -> dict[str, float]:
        """Current speeds, normalised to the slowest healthy tier — the f
        vector the batch partitioner consumes."""
        act = {n: h.ewma_thr for n, h in self.tiers.items()
               if not h.excluded and h.n_obs > 0}
        if not act:
            return {}
        lo = min(act.values()) or 1.0
        return {n: v / lo for n, v in act.items()}
