"""Parameter definition trees.

A model declares its parameters once as a pytree of :class:`ParamDef`
(shape + logical axes + init). From that single declaration we derive:

* ``abstract(defs, ctx)``   — ShapeDtypeStructs with NamedShardings (dry-run;
  no host/device allocation — required for the 236 B-param configs).
* ``materialize(defs, key)``— real initialised arrays (smoke tests, examples).
* ``specs(defs, ctx)``      — PartitionSpec tree (for jit in_shardings).
* ``stack(defs, n)``        — prepend a ``layers`` axis (scan-over-layers).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.axes import ShardCtx


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"       # normal | zeros | ones | scaled (out-proj)
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def pd(shape, axes, init="normal", scale=0.02, dtype=jnp.bfloat16) -> ParamDef:
    return ParamDef(tuple(int(s) for s in shape), tuple(axes), init, scale, dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map(f, tree):
    return jax.tree.map(f, tree, is_leaf=is_def)


def stack(defs, n: int):
    """Stack a block's defs along a new leading `layers` axis (for lax.scan)."""
    return tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale,
                           d.dtype),
        defs)


def abstract(defs, ctx: ShardCtx):
    return tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype,
                                       sharding=ctx.sharding(d.axes, d.shape)),
        defs)


def specs(defs, ctx: ShardCtx):
    return tree_map(lambda d: ctx.spec(d.axes, d.shape), defs)


def shardings(defs, ctx: ShardCtx):
    return tree_map(lambda d: ctx.sharding(d.axes, d.shape), defs)


def n_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)


def _init_leaf(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    scale = d.scale
    if d.init == "scaled":  # residual-output projections: 0.02/sqrt(2L) handled by caller
        scale = d.scale
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)


def materialize(defs, key: jax.Array):
    """Deterministic init: every leaf's key is fold_in(path-hash).

    crc32, not builtin hash(): string hashes are salted per process, which
    made "deterministic" init differ between two runs of the same script.
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)
    out = []
    for path, d in leaves:
        pstr = "/".join(str(p) for p in path)
        k = jax.random.fold_in(key, zlib.crc32(pstr.encode()) % (2**31))
        out.append(_init_leaf(d, k))
    return jax.tree.unflatten(treedef, out)


def materialize_sharded(defs, key: jax.Array, ctx: ShardCtx):
    """jit-init directly into the target shardings (no host round-trip)."""
    sh = shardings(defs, ctx)
    flat_sh = jax.tree.leaves(sh)

    def init_fn(k):
        return materialize(defs, k)

    return jax.jit(init_fn, out_shardings=jax.tree.unflatten(
        jax.tree.structure(sh), flat_sh))(key)
