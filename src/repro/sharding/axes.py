"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Every parameter / activation dimension carries a *logical* axis name; a rule
table maps logical names to mesh axes. Rules silently drop a mesh axis when
the dimension size is not divisible by the mesh-axis size (e.g. 8 KV heads on
a 16-way ``model`` axis → replicated), which keeps one rule table valid for
all 10 architectures.

All model code threads a :class:`ShardCtx` (mesh + rules) explicitly; with a
single-device mesh every constraint is a no-op, so the same code path runs in
CPU smoke tests and in the 512-chip dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary (see DESIGN.md §3):
#   batch     global batch                     → (pod, data)
#   seq       sequence (residual stream, SP)   → model
#   kv_seq    decode KV-cache sequence         → model   (flash-decoding)
#   embed     d_model (params; FSDP)           → data   [+ pod for huge models]
#   vocab     vocabulary                       → model
#   heads     query heads                      → model
#   kv_heads  kv heads                         → model (if divisible)
#   mlp       ffn hidden                       → model
#   experts   MoE expert axis                  → model (EP)
#   d_inner   mamba inner channels             → model
#   layers    stacked scan axis                → None
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),
    "kv_seq": ("model",),
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qk": (),
    "v": (),
    "mlp": ("model",),
    "experts": ("model",),
    "layers": (),
    "d_inner": ("model",),
    "ssm_state": (),
    "ssm_heads": ("model",),
    "conv": (),
    "lora": (),
    "frontend": (),
    "null": (),
}

# For very large models (≳100 B params) optimizer state must shard over the
# pod axis too, otherwise a 16 GB v5e chip cannot hold its slice.
ZERO_POD_RULES = dict(DEFAULT_RULES, embed=("pod", "data"), experts=("model",))


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + rule table threaded through all model code."""
    mesh: Mesh
    rules: Mapping[str, tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    @property
    def model_axis(self) -> str:
        return "model"

    @property
    def data_axes(self) -> tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)

    def spec(self, axes: Sequence[str | None], shape: Sequence[int]) -> P:
        return logical_to_spec(axes, shape, self.mesh, self.rules)

    def sharding(self, axes: Sequence[str | None], shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
        if self.mesh.empty or self.mesh.size == 1:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(axes, x.shape))


def single_device_ctx() -> ShardCtx:
    """1-device mesh with the production axis names — used by smoke tests."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    return ShardCtx(mesh=mesh)


def mesh_axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    return math.prod(mesh.shape.get(n, 1) for n in names)


def logical_to_spec(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-divisible axes."""
    rules = rules or DEFAULT_RULES
    spec: list = []
    used: set[str] = set()
    for dim, name in zip(shape, axes):
        if name is None:
            spec.append(None)
            continue
        mesh_axes = [a for a in rules.get(name, ()) if a in mesh.shape and a not in used]
        # keep the largest divisible prefix of the rule's mesh axes
        keep: list[str] = []
        prod = 1
        for a in mesh_axes:
            if mesh.shape[a] > 1 and dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
            elif mesh.shape[a] == 1:
                continue
            else:
                break
        used.update(keep)
        if not keep:
            spec.append(None)
        elif len(keep) == 1:
            spec.append(keep[0])
        else:
            spec.append(tuple(keep))
    return P(*spec)


def named_sharding(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))
