"""Tiled GEMM Pallas kernel — TPU-native rebuild of the paper's §4 benchmark.

The Fig. 4 row×column tiling becomes a (M/bm, N/bn, K/bk) grid with fp32
accumulation in a VMEM scratch tile; the paper's "buffered columns"
capacity knob (32 on Zynq / 128 on ZynqUS+, limited by BRAM) becomes the
``bn`` block dimension, bounded by VMEM (16 MiB) and MXU alignment (128).
benchmarks/bench_gemm.py sweeps it exactly like Table 2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams → CompilerParams in newer jax
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
         bk: int = 512, interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit VMEM tiling. Shapes must divide the blocks."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape,
                                                         (bm, bn, bk))
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        # M/N grid axes carry independent output tiles → megacore-parallel;
        # K is the fp32 accumulation and must stay sequential
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 2) -> int:
    """Working-set estimate for block-shape selection (the capacity law)."""
    return (bm * bk + bk * bn) * itemsize + bm * bn * 4
