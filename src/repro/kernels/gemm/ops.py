"""Public GEMM op: Pallas on TPU, interpret-mode on CPU, plus the
HBB heterogeneous-grid mode (the paper-faithful row split)."""
from __future__ import annotations

import jax

from repro.kernels.gemm.gemm import gemm
from repro.kernels.gemm.ref import gemm_ref


def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512) -> jax.Array:
    interpret = jax.default_backend() == "cpu"
    return gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


def matmul_row_split(a, b, split: int, fast_fn=None, slow_fn=None):
    """Paper mode: rows [0, split) to the accelerator-class executor, the
    rest to the core-class executor (HBB decides `split`)."""
    fast_fn = fast_fn or matmul
    slow_fn = slow_fn or gemm_ref
    top = fast_fn(a[:split], b) if split else None
    bot = slow_fn(a[split:], b) if split < a.shape[0] else None
    import jax.numpy as jnp
    if top is None:
        return bot
    if bot is None:
        return top
    return jnp.concatenate([top, bot], axis=0)
