"""Grouped (per-expert) GEMM Pallas kernel — the MoE expert matmul.

Computes ``out[e] = buf[e] @ w[e]`` for the capacity-dispatch buffers of
:mod:`repro.models.moe` (megablox-lite). Grid (E, M/bm, N/bn, K/bk), fp32
VMEM accumulator, expert index outermost so each expert's weight tiles are
streamed once per (m, n) supertile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_gemm(a: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
                 bk: int = 512, interpret: bool = False) -> jax.Array:
    """a (E, M, K) @ w (E, K, N) → (E, M, N)."""
    E, M, K = a.shape
    E2, K2, N = w.shape
    assert E == E2 and K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    grid = (E, M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, w)
