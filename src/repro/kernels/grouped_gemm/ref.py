"""Pure-jnp oracle for the grouped GEMM kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def grouped_gemm_ref(a: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("emk,ekn->emn", a, w,
                      preferred_element_type=jnp.float32).astype(a.dtype)
