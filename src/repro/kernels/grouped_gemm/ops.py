"""Public grouped-GEMM op: Pallas on TPU, interpret-mode on CPU."""
from __future__ import annotations

import jax

from repro.kernels.grouped_gemm.grouped_gemm import grouped_gemm


def expert_matmul(a, w, *, bm=128, bn=128, bk=512):
    interpret = jax.default_backend() == "cpu"
    return grouped_gemm(a, w, bm=bm, bn=bn, bk=bk, interpret=interpret)
