"""Pallas paged flash-decode attention — index the page table in-kernel.

The jnp paged path (the gathered-view oracle in ``ref.py``, formerly
`serve/decode.py::_paged_gather`) materializes a position-ordered
`(B, T·page_size, …)` copy of every slot's pages in HBM per layer, per
token, inside the quantum scan. This kernel never builds
that view: the grid is `(B, T)` with the page dimension innermost, the
page table and per-slot positions ride in as *scalar prefetch* operands
(`pltpu.PrefetchScalarGridSpec`), and each grid step DMAs exactly one
page's K/V block straight from the shared pool into VMEM — the BlockSpec
index map reads `pt[b, t]`, so the gather happens in the DMA engine, not
as an HBM-resident copy.

Attention is blockwise online softmax: `(acc, m, l)` carries live in VMEM
scratch across the page dimension, exactly as in
``kernels/flash_attention``. Table entries whose first position lies past
the slot's `pos` are skipped with ``pl.when`` (dead pages — including the
reserved trash page 0 that absorbs inactive-slot scribbles — cost no
FLOPs), and the tail page is position-masked. The kernel runs *per model
shard* inside the decode `shard_map`, so it returns **unnormalized**
`(o, m, l)` partials; the caller's exact-softmax `_combine` across the
``model`` axis is unchanged. The in-page write of the new token's K/V
stays a separate masked scatter outside the kernel (`_paged_write`): a
scatter through the table is one tiny row per slot — doing it in-kernel
would force the pool to be an aliased in/out operand for no bandwidth win.

Layouts (per shard; ``ps`` = page_size // msize, ``base`` = shard·ps):
  GQA: q (B, Hkv, G, dh); pools (N, ps, Hkv, dh) ×2 → o (B, Hkv·G, dh).
  MLA: q (B, H, R);       pool  (N, ps, R)          → o (B, H, kv_lora)
       (the cache row is both key and value — MQA-style absorbed MLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30

# renamed TPUCompilerParams → CompilerParams in newer jax
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _online_update(s, ok, acc_ref, m_ref, l_ref, ov):
    """One page block of flash accumulation. s (H, ps) masked scores, ok
    (H, ps) validity, ov(p) → (H, dv) value product for probabilities p."""
    m_old = m_ref[:, :1]                                   # (H, 1)
    m_blk = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_old, m_blk)
    m_safe = jnp.where(m_new <= NEG / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(jnp.where(m_old <= NEG / 2, NEG, m_old) - m_safe)
    acc_ref[...] = acc_ref[...] * corr + ov(p)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)


def _store_partials(o_ref, m_ref_o, l_ref_o, acc_ref, m_ref, l_ref):
    """Emit the shard-local (o, m, l) partials for the cross-shard combine.
    o stays UNNORMALIZED — `_combine` rescales by exp(m - m_global) and
    divides by the psum'd l, so fully-masked shards contribute zero."""
    o_ref[0] = acc_ref[...]
    m_ref_o[0] = m_ref[:, 0]
    l_ref_o[0] = l_ref[:, 0]


def _gqa_kernel(pt_ref, pos_ref, base_ref, q_ref, k_ref, v_ref,
                o_ref, m_out, l_out, acc_ref, m_ref, l_ref, *,
                page_size: int, hkv: int, grp: int, nt: int, softcap: float,
                scale: float):
    b = pl.program_id(0)
    t = pl.program_id(1)
    ps = k_ref.shape[1]                                    # per-shard offsets
    H = hkv * grp

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    first = t * page_size + base_ref[0]                    # global pos of off 0

    @pl.when(first <= pos)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale           # (Hkv, G, dh)
        k = k_ref[0].astype(jnp.float32)                   # (ps, Hkv, dh)
        v = v_ref[0].astype(jnp.float32)                   # (ps, Hkv, dh)
        # per-kv-head 2D dots (static unroll — Hkv is a config constant)
        s = jnp.concatenate(
            [jax.lax.dot_general(q[h], k[:, h], (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             for h in range(hkv)], axis=0)                 # (H, ps)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        gpos = first + jax.lax.broadcasted_iota(jnp.int32, (H, ps), 1)
        ok = gpos <= pos
        s = jnp.where(ok, s, NEG)

        def ov(p):                                         # (H, ps) → (H, dh)
            return jnp.concatenate(
                [jax.lax.dot_general(p[h * grp:(h + 1) * grp], v[:, h],
                                     (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
                 for h in range(hkv)], axis=0)

        _online_update(s, ok, acc_ref, m_ref, l_ref, ov)

    @pl.when(t == nt - 1)
    def _store():
        _store_partials(o_ref, m_out, l_out, acc_ref, m_ref, l_ref)


def _mla_kernel(pt_ref, pos_ref, base_ref, q_ref, c_ref,
                o_ref, m_out, l_out, acc_ref, m_ref, l_ref, *,
                page_size: int, kv_lora: int, nt: int, scale: float):
    b = pl.program_id(0)
    t = pl.program_id(1)
    ps = c_ref.shape[1]
    H = q_ref.shape[1]

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    first = t * page_size + base_ref[0]

    @pl.when(first <= pos)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale           # (H, R)
        c = c_ref[0].astype(jnp.float32)                   # (ps, R)
        s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        gpos = first + jax.lax.broadcasted_iota(jnp.int32, (H, ps), 1)
        ok = gpos <= pos
        s = jnp.where(ok, s, NEG)

        def ov(p):                                         # value = row[:lora]
            return jax.lax.dot_general(p, c[:, :kv_lora],
                                       (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

        _online_update(s, ok, acc_ref, m_ref, l_ref, ov)

    @pl.when(t == nt - 1)
    def _store():
        _store_partials(o_ref, m_out, l_out, acc_ref, m_ref, l_ref)


@functools.partial(jax.jit, static_argnames=("page_size", "scale", "softcap",
                                             "interpret"))
def paged_flash_decode_gqa(q, pool_k, pool_v, page_table, pos, base, *,
                           page_size: int, scale: float, softcap: float = 0.0,
                           interpret: bool = False):
    """q (B,Hkv,G,dh); pools (N, ps, Hkv, dh); page_table (B, T) int32;
    pos (B,) int32; base () int32 shard offset (shard_idx · ps).
    → unnormalized partials o (B, Hkv·G, dh) f32, m/l (B, Hkv·G) f32."""
    B, hkv, grp, dh = q.shape
    ps = pool_k.shape[1]
    T = page_table.shape[1]
    H = hkv * grp
    grid = (B, T)
    scalars = (page_table.astype(jnp.int32), pos.astype(jnp.int32),
               jnp.asarray(base, jnp.int32).reshape(1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hkv, grp, dh), lambda b, t, pt, p, o: (b, 0, 0, 0)),
            pl.BlockSpec((1, ps, hkv, dh), lambda b, t, pt, p, o: (pt[b, t], 0, 0, 0)),
            pl.BlockSpec((1, ps, hkv, dh), lambda b, t, pt, p, o: (pt[b, t], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, dh), lambda b, t, pt, p, o: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, t, pt, p, o: (b, 0)),
            pl.BlockSpec((1, H), lambda b, t, pt, p, o: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, dh), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    kern = functools.partial(_gqa_kernel, page_size=page_size, hkv=hkv,
                             grp=grp, nt=T, softcap=softcap, scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        # the page axis carries the (acc, m, l) flash state → sequential;
        # batch rows are independent
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*scalars, q, pool_k, pool_v)


@functools.partial(jax.jit, static_argnames=("page_size", "kv_lora", "scale",
                                             "interpret"))
def paged_flash_decode_mla(q, pool, page_table, pos, base, *,
                           page_size: int, kv_lora: int, scale: float,
                           interpret: bool = False):
    """q (B,H,R); pool (N, ps, R); → o (B, H, kv_lora), m/l (B, H) f32
    partials. The pool row is both key (all R dims) and value (first
    kv_lora dims) — absorbed-MLA decode."""
    B, H, R = q.shape
    ps = pool.shape[1]
    T = page_table.shape[1]
    grid = (B, T)
    scalars = (page_table.astype(jnp.int32), pos.astype(jnp.int32),
               jnp.asarray(base, jnp.int32).reshape(1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, R), lambda b, t, pt, p, o: (b, 0, 0)),
            pl.BlockSpec((1, ps, R), lambda b, t, pt, p, o: (pt[b, t], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, kv_lora), lambda b, t, pt, p, o: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, t, pt, p, o: (b, 0)),
            pl.BlockSpec((1, H), lambda b, t, pt, p, o: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, kv_lora), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
        ],
    )
    kern = functools.partial(_mla_kernel, page_size=page_size,
                             kv_lora=kv_lora, nt=T, scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, kv_lora), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*scalars, q, pool)
