"""Pure-jnp reference for the paged flash-decode kernel.

Computes the SAME shard-local unnormalized (o, m, l) partials as
``paged_attention.py`` by materializing the gathered view — this is the
equivalence oracle for the kernel tests, deliberately written in the
"generic" style the kernel replaces (one `jnp.take` over the page table,
direct global-max softmax). Numerics: both paths reduce in f32; the
online-softmax rescaling in the kernel is algebraically identical to the
single-max form here, so they agree to f32 round-off.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30
F32 = jnp.float32


def _gathered(pool, page_table, base, page_size):
    """pool (N, ps, …) + pt (B, T) → (view (B, T·ps, …), gpos (B, T·ps))
    global positions per gathered offset for this shard (offset `base`)."""
    ps = pool.shape[1]
    B, T = page_table.shape
    g = jnp.take(pool, page_table, axis=0)                 # (B, T, ps, …)
    g = g.reshape((B, T * ps) + pool.shape[2:])
    gpos = (jnp.arange(T)[:, None] * page_size + base +
            jnp.arange(ps)[None]).reshape(-1)
    return g, jnp.broadcast_to(gpos[None], (B, T * ps))


def paged_flash_decode_gqa_ref(q, pool_k, pool_v, page_table, pos, base, *,
                               page_size: int, scale: float,
                               softcap: float = 0.0):
    """Same contract as the kernel: q (B,Hkv,G,dh), pools (N,ps,Hkv,dh) →
    (o (B,Hkv·G,dh), m (B,Hkv·G), l (B,Hkv·G)) f32 partials."""
    B, hkv, grp, dh = q.shape
    gk, gpos = _gathered(pool_k, page_table, base, page_size)
    gv, _ = _gathered(pool_v, page_table, base, page_size)
    valid = gpos <= pos[:, None]                           # (B, S)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(F32) * scale, gk.astype(F32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[:, None, None], s, NEG)
    m = jnp.max(s, -1)                                     # (B, Hkv, G)
    m_safe = jnp.where(m <= NEG / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    o = jnp.einsum("bhgs,bshd->bhgd", p, gv.astype(F32))   # (B, Hkv, G, dh)
    l = jnp.sum(p, -1)
    H = hkv * grp
    return o.reshape(B, H, dh), m.reshape(B, H), l.reshape(B, H)


def paged_flash_decode_mla_ref(q, pool, page_table, pos, base, *,
                               page_size: int, kv_lora: int, scale: float):
    """q (B,H,R); pool (N, ps, R) → (o (B,H,kv_lora), m, l) f32 partials."""
    g, gpos = _gathered(pool, page_table, base, page_size)
    valid = gpos <= pos[:, None]
    s = jnp.einsum("bhr,bsr->bhs", q.astype(F32) * scale, g.astype(F32))
    s = jnp.where(valid[:, None], s, NEG)
    m = jnp.max(s, -1)
    m_safe = jnp.where(m <= NEG / 2, 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[:, None], p, 0.0)
    o = jnp.einsum("bhs,bsr->bhr", p, g[..., :kv_lora].astype(F32))
    return o, m, jnp.sum(p, -1)
