"""Public paged-attention decode ops with backend dispatch.

Called per model shard from inside the decode `shard_map`
(`serve/decode.py`): inputs are the shard-local page pools and the traced
shard index, outputs the unnormalized (o, m, l) softmax partials the
caller feeds to the cross-shard exact `_combine`.

Dispatch (``impl`` arg / `PAGED_KERNEL_BACKEND` env):
  "auto"      TPU → compiled Pallas kernel; other backends → "ref". The
              Pallas interpreter is an emulator (~50× the fused-XLA cost),
              so it is never a default *serving* path off-TPU.
  "kernel"    the Pallas kernel, interpret mode off-TPU.
  "interpret" the Pallas kernel, interpret mode everywhere — what the
              tier-1 tests pin so the real kernel body is exercised on
              CPU on every run (tests/test_paged_kernel.py).
  "ref"       the jnp oracle in ``ref.py`` — same blockwise contract
              (shard-local partials over the live table prefix), fused by
              XLA. Off-TPU serving default.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.paged_attention import (
    paged_flash_decode_gqa, paged_flash_decode_mla)

_IMPLS = ("auto", "kernel", "interpret", "ref")


def _resolve(impl: str) -> tuple[str, bool]:
    """→ (path, interpret) where path ∈ {"kernel", "ref"}. The env override
    is read per call so it works however late the module was imported."""
    impl = impl or os.environ.get("PAGED_KERNEL_BACKEND", "auto")
    if impl not in _IMPLS:
        raise ValueError(f"paged-attention impl {impl!r}: expected one of "
                         f"{_IMPLS}")
    tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        impl = "kernel" if tpu else "ref"
    if impl == "ref":
        return "ref", False
    return "kernel", impl == "interpret" or not tpu


def paged_attend_gqa(q, pool_k, pool_v, page_table, pos, shard, msize, *,
                     scale: float, softcap: float = 0.0, impl: str = ""):
    """q (B,Hkv,G,dh); pools (N, ps_loc, Hkv, dh); page_table (B,T);
    pos (B,); shard = traced model-axis index; msize its static size.
    → (o (B,Hkv·G,dh), m (B,Hkv·G), l (B,Hkv·G)) f32 partials."""
    ps_loc = pool_k.shape[1]
    page_size = ps_loc * msize
    base = shard * ps_loc
    path, interpret = _resolve(impl)
    if path == "ref":
        return ref.paged_flash_decode_gqa_ref(
            q, pool_k, pool_v, page_table, pos, base,
            page_size=page_size, scale=scale, softcap=softcap)
    return paged_flash_decode_gqa(
        q, pool_k, pool_v, page_table, pos, base, page_size=page_size,
        scale=scale, softcap=softcap, interpret=interpret)


def paged_attend_mla(q, pool, page_table, pos, shard, msize, *,
                     kv_lora: int, scale: float, impl: str = ""):
    """q (B,H,R); pool (N, ps_loc, R) → (o (B,H,kv_lora), m, l) partials."""
    ps_loc = pool.shape[1]
    page_size = ps_loc * msize
    base = shard * ps_loc
    path, interpret = _resolve(impl)
    if path == "ref":
        return ref.paged_flash_decode_mla_ref(
            q, pool, page_table, pos, base, page_size=page_size,
            kv_lora=kv_lora, scale=scale)
    return paged_flash_decode_mla(
        q, pool, page_table, pos, base, page_size=page_size,
        kv_lora=kv_lora, scale=scale, interpret=interpret)
