"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def ssd_intra_chunk_ref(x, cs, B, C):
    """x (G,Q,P), cs (G,Q,1), B/C (G,Q,N) → y (G,Q,P), states (G,N,P)."""
    x = x.astype(jnp.float32)
    cs = cs.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    Q = x.shape[1]
    seg = cs[:, :, 0][:, :, None] - cs[:, :, 0][:, None, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None], jnp.exp(seg), 0.0)
    att = jnp.einsum("gtn,gsn->gts", C, B) * L
    y = jnp.einsum("gts,gsp->gtp", att, x)
    decay_end = jnp.exp(cs[:, -1:, :] - cs)
    st = jnp.einsum("gsn,gsp->gnp", B * decay_end, x)
    return y, st
