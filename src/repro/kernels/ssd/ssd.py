"""Mamba-2 SSD intra-chunk Pallas kernel.

Computes the quadratic within-chunk part of the state-space-dual form for
one (batch·chunk, head) grid cell:

    L[t,s]   = exp(cs[t] - cs[s])·1[t ≥ s]
    y_diag   = ((C Bᵀ) ⊙ L) @ x                      (Q,P)
    state    = (B ⊙ exp(cs[-1] - cs))ᵀ @ x           (N,P)  chunk-final state

The inter-chunk recurrence stays a `lax.scan` outside (linear in T). VMEM
working set: Q² + Q·(2N+2P) fp32 — Q=256, N=128, P=64 → ~0.6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, cs_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    cs = cs_ref[0].astype(jnp.float32)        # (Q, 1)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    seg = cs - cs.T                            # (Q, Q): cs[t] - cs[s]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    att = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32) * L
    y_ref[0] = jnp.dot(att, x, preferred_element_type=jnp.float32
                       ).astype(y_ref.dtype)

    decay_end = jnp.exp(cs[-1:] - cs)          # (Q, 1) broadcast over N
    st = jax.lax.dot_general(B * decay_end, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    st_ref[0] = st.astype(st_ref.dtype)        # (N, P)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, cs, B, C, *, interpret: bool = False):
    """x (G,Q,P), cs (G,Q,1), B/C (G,Q,N) → y (G,Q,P), states (G,N,P).

    G = batch·chunks·heads flattened; caller folds dt into x and supplies
    the inclusive cumsum `cs` of dt·A per head.
    """
    G, Q, P = x.shape
    N = B.shape[-1]
    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, N, P), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((G, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, cs, B, C)
    return y, st
