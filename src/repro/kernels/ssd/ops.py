"""Public SSD intra-chunk op: Pallas on TPU, interpret-mode on CPU."""
from __future__ import annotations

import jax

from repro.kernels.ssd.ssd import ssd_intra_chunk


def intra_chunk(x, cs, B, C):
    interpret = jax.default_backend() == "cpu"
    return ssd_intra_chunk(x, cs, B, C, interpret=interpret)
