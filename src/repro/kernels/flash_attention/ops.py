"""Public flash-attention op: Pallas kernel (interpret on CPU)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention


def attend(q, k, v, *, scale, causal=True, window=0, softcap=0.0,
           bq=512, bk=512):
    interpret = jax.default_backend() == "cpu"
    return flash_attention(q, k, v, scale=scale, causal=causal, window=window,
                           softcap=softcap, bq=bq, bk=bk, interpret=interpret)
