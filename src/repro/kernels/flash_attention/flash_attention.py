"""Flash-attention Pallas kernel (forward), causal / sliding-window.

Grid = (B·H, nq, nk) with the KV dimension innermost and flash (o, m, l)
accumulators in VMEM scratch; fully-masked KV blocks are skipped with
``pl.when`` (causality → ~2× fewer live blocks; sliding window → O(T·w)).
This is the TPU path for the XLA-level ``attend_chunked`` (same block-pair
enumeration, same online softmax — cross-validated in tests).

Layout: one (batch, head) pair per grid row — q (B,H,T,dh) contiguous in T,
so each block load is a (bq, dh) VMEM tile; dh is the minor dim (128-align).
The matching backward kernels live in ``flash_attention_bwd.py`` (dq and
dk/dv passes with accumulator-local grids); both are validated against the
pure-jnp oracle in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, nk: int,
                  bq: int, bk: int, softcap: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * bq
    k0 = kj * bk
    # block-level skip: causal ⇒ k0 ≤ q0+bq-1 ; window ⇒ k0+bk-1 > q0-window
    conds = []
    if causal:
        conds.append(k0 <= q0 + bq - 1)
    if window:
        conds.append(k0 + bk - 1 > q0 - window)
    live = functools.reduce(jnp.logical_and, conds) if conds else None

    def _block():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                    # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        iq = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        jk = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= jk <= iq
        if window:
            ok &= jk > iq - window
        s = jnp.where(ok, s, NEG)

        m_old = m_ref[:, :1]                                 # (bq,1)
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_old, m_blk)
        m_safe = jnp.where(m_new <= NEG / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe)
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(jnp.where(m_old <= NEG / 2, NEG, m_old) - m_safe)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    if live is None:
        _block()
    else:
        pl.when(live)(_block)

    @pl.when(kj == nk - 1)
    def _store():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B,H,Tq,dh), k/v (B,H,Tk,dh) [GQA pre-broadcast] → (B,H,Tq,dv)."""
    B, H, Tq, dh = q.shape
    Tk = k.shape[2]
    dv = v.shape[3]
    bq, bk = min(bq, Tq), min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, Tk, bq, bk)
    grid = (B * H, Tq // bq, Tk // bk)
    qr = q.reshape(B * H, Tq, dh)
    kr = k.reshape(B * H, Tk, dh)
    vr = v.reshape(B * H, Tk, dv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, nk=Tk // bk, bq=bq, bk=bk,
                          softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Tq, dv)
