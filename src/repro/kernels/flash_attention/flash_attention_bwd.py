"""Flash-attention backward Pallas kernels (TPU training path).

Same math as the XLA custom-VJP (`repro.models.attention._attend_bwd`):
recompute p per block from the saved lse, then

    dv_j += pᵀ do_i
    ds    = p ⊙ (do_i vᵀ − delta_i)          delta = rowsum(do ⊙ o)
    dq_i += ds k_j · scale ;  dk_j += dsᵀ q_i · scale

Split into two kernels so every accumulator is local to its grid row
(no cross-block races): dq iterates (q-block ⨯ kv-blocks-innermost), dkv
iterates (kv-block ⨯ q-blocks-innermost). Causal/sliding-window block
skipping mirrors the forward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _masks(q0, k0, bq, bk, causal, window):
    iq = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    jk = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= jk <= iq
    if window:
        ok &= jk > iq - window
    return ok


def _block_live(q0, k0, bq, bk, causal, window):
    conds = []
    if causal:
        conds.append(k0 <= q0 + bq - 1)
    if window:
        conds.append(k0 + bk - 1 > q0 - window)
    return functools.reduce(jnp.logical_and, conds) if conds else None


def _p_and_ds(q, k, v, do, lse, delta, *, scale, softcap, ok):
    s_pre = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    if softcap:
        t = jnp.tanh(s_pre / softcap)
        s = t * softcap
    else:
        t, s = None, s_pre
    s = jnp.where(ok, s, NEG)
    p = jnp.exp(s - lse)
    p = jnp.where(ok, p, 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if softcap:
        ds = ds * (1.0 - t * t)
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dq_ref, acc_ref,
               *, scale, causal, window, softcap, nk, bq, bk):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q0, k0 = qi * bq, kj * bk
        ok = _masks(q0, k0, bq, bk, causal, window)
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        _, ds = _p_and_ds(q, k, v_ref[0].astype(jnp.float32),
                          do_ref[0].astype(jnp.float32),
                          lse_ref[0][:, :1], dl_ref[0][:, :1],
                          scale=scale, softcap=softcap, ok=ok)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    live = _block_live(qi * bq, kj * bk, bq, bk, causal, window)
    if live is None:
        body()
    else:
        pl.when(live)(body)

    @pl.when(kj == nk - 1)
    def _():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dl_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, scale, causal, window, softcap, nq, bq, bk):
    kj, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def body():
        q0, k0 = qi * bq, kj * bk
        ok = _masks(q0, k0, bq, bk, causal, window)
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, ds = _p_and_ds(q, k, v_ref[0].astype(jnp.float32), do,
                          lse_ref[0][:, :1], dl_ref[0][:, :1],
                          scale=scale, softcap=softcap, ok=ok)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    live = _block_live(qi * bq, kj * bk, bq, bk, causal, window)
    if live is None:
        body()
    else:
        pl.when(live)(body)

    @pl.when(qi == nq - 1)
    def _():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, scale, causal=True, window=0,
                        softcap=0.0, bq=256, bk=256, interpret=False):
    """q/k (B,H,T,dh), v/o/do (B,H,T,dv), lse (B,H,T) → (dq, dk, dv)."""
    B, H, Tq, dh = q.shape
    Tk, dv_ = k.shape[2], v.shape[3]
    bq, bk = min(bq, Tq), min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    args = [x.reshape(B * H, x.shape[2], -1) for x in (q, k, v, do)]
    lse_r = lse.reshape(B * H, Tq, 1)
    dl_r = delta.reshape(B * H, Tq, 1)

    common = dict(scale=scale, causal=causal, window=window, softcap=softcap,
                  bq=bq, bk=bk)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, nk=Tk // bk, **common),
        grid=(B * H, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv_), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq, dv_), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(args[0], args[1], args[2], args[3], lse_r, dl_r)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, nq=Tq // bq, **common),
        grid=(B * H, Tk // bk, Tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, dv_), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, dv_), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, j, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, dh), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, dv_), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tk, dh), k.dtype),
            jax.ShapeDtypeStruct((B * H, Tk, dv_), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, dh), jnp.float32),
                        pltpu.VMEM((bk, dv_), jnp.float32)],
        interpret=interpret,
    )(args[0], args[1], args[2], args[3], lse_r, dl_r)
    rs = lambda x: x.reshape(B, H, x.shape[1], x.shape[2])
    return rs(dq), rs(dk), rs(dv)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd_lse(q, k, v, *, scale, causal=True, window=0,
                            softcap=0.0, bq=256, bk=256, interpret=False):
    """Forward that also returns lse (residual for the bwd kernels)."""
    from repro.kernels.flash_attention.flash_attention import flash_attention
    o = flash_attention(q, k, v, scale=scale, causal=causal, window=window,
                        softcap=softcap, bq=bq, bk=bk, interpret=interpret)
    # lse via a cheap jnp pass (numerically matches the kernel's masks)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    Tq, Tk = q.shape[2], k.shape[2]
    iq = jnp.arange(Tq)[:, None]
    jk = jnp.arange(Tk)[None, :]
    ok = jnp.ones((Tq, Tk), bool)
    if causal:
        ok &= jk <= iq
    if window:
        ok &= jk > iq - window
    s = jnp.where(ok[None, None], s, NEG)
    lse = jax.scipy.special.logsumexp(s, axis=-1)
    return o, lse
