"""Pure-jnp oracle for the flash-attention kernel (O(T²) memory)."""
import functools

import jax
import jax.numpy as jnp

NEG = -1e30


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "softcap"))
def flash_attention_ref(q, k, v, *, scale, causal=True, window=0,
                        softcap=0.0):
    """q (B,H,Tq,dh), k/v (B,H,Tk,d*) → (B,H,Tq,dv)."""
    Tq, Tk = q.shape[2], k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    iq = jnp.arange(Tq)[:, None]
    jk = jnp.arange(Tk)[None, :]
    ok = jnp.ones((Tq, Tk), bool)
    if causal:
        ok &= jk <= iq
    if window:
        ok &= jk > iq - window
    s = jnp.where(ok[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
