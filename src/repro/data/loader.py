"""Sharded host data loader with background prefetch.

Each host process would load only its shard of the global batch
(``shard_index``/``num_shards``); arrays go device-side with the batch
sharding via ``device_put``, and a small prefetch queue overlaps host data
generation with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax

from repro.sharding.axes import ShardCtx


class PrefetchLoader:
    def __init__(self, source: Iterator[dict], ctx: ShardCtx | None = None,
                 prefetch: int = 2, shard_index: int = 0, num_shards: int = 1):
        self.source = source
        self.ctx = ctx
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _place(self, batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            if self.num_shards > 1:
                n = len(v) // self.num_shards
                v = v[self.shard_index * n:(self.shard_index + 1) * n]
            if self.ctx is not None and self.ctx.mesh.size > 1:
                axes = ("batch",) + (None,) * (v.ndim - 1)
                out[k] = jax.device_put(v, self.ctx.sharding(axes, v.shape))
            else:
                out[k] = jax.numpy.asarray(v)
        return out

    def _work(self) -> None:
        try:
            for batch in self.source:
                if self._stop.is_set():
                    return
                self.q.put(self._place(batch))
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self) -> None:
        self._stop.set()
