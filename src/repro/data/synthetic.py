"""Deterministic synthetic LM data: a mixture of Zipfian unigrams and copy
patterns so a real model can visibly *learn* (loss drops below unigram
entropy when it exploits the copy structure) — used by the end-to-end
training example and integration tests.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, seed: int = 0,
                 copy_period: int = 8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.copy_period = copy_period
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.probs = (1 / ranks) / np.sum(1 / ranks)

    def batch(self, n: int) -> dict[str, np.ndarray]:
        S = self.seq_len
        toks = self.rng.choice(self.vocab, size=(n, S + 1), p=self.probs)
        # every copy_period-th token repeats the token copy_period before it
        for off in range(self.copy_period, S + 1, self.copy_period):
            toks[:, off] = toks[:, off - self.copy_period]
        toks = toks.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((n, S), np.float32),
        }

    def iterator(self, batch_size: int):
        while True:
            yield self.batch(batch_size)
