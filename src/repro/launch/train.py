"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --steps 50 --batch 8 --seq 128 [--smoke] [--microbatches 2] \
        [--compression int8] [--ckpt-dir /tmp/ckpt]

On this CPU container you train reduced (--smoke) configs; on a real slice
the same entrypoint drives the production mesh (the dry-run proves the full
configs lower + compile there).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticLM
from repro.sharding.axes import single_device_ctx
from repro.train.compression import CompressionConfig
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--moments", choices=["float32", "int8"],
                    default="float32")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = single_device_ctx()
    ocfg = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                     decay_steps=args.steps, moments_dtype=args.moments)
    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir)
    ccfg = CompressionConfig(kind=args.compression)
    data = SyntheticLM(cfg.vocab, args.seq, seed=args.seed)
    loader = PrefetchLoader(data.iterator(args.batch), ctx)

    def log(step, row):
        if step % max(1, args.steps // 20) == 0:
            print(f"step {step:5d} loss {row['loss']:.4f} "
                  f"|g| {row['grad_norm']:.3f} lr {row['lr']:.2e} "
                  f"{row['tokens'] / row['dt']:.0f} tok/s")

    res = train_loop(cfg, ocfg, lcfg, ctx, iter(loader), ccfg=ccfg,
                     on_step=log, seed=args.seed)
    print(f"done: {len(res.history)} steps, restarts={res.restarts}, "
          f"resumed_from={res.resumed_from}, "
          f"final loss {res.history[-1]['loss']:.4f}")
    loader.close()


if __name__ == "__main__":
    main()
