"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module constant — importing this module never touches jax
device state (smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax

from repro.sharding.axes import DEFAULT_RULES, ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_ctx(*, multi_pod: bool = False, rules=None) -> ShardCtx:
    mesh = make_production_mesh(multi_pod=multi_pod)
    return ShardCtx(mesh=mesh, rules=dict(rules or DEFAULT_RULES))


# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per-direction)
VMEM_BYTES = 16 * 2 ** 20
HBM_BYTES = 16 * 2 ** 30
