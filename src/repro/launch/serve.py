"""Serving launcher: continuous-batching engine on a (smoke) config.

    PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
        --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, smoke_config
from repro.serve.engine import Engine, Request, make_engine
from repro.sharding.axes import single_device_ctx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    ctx = single_device_ctx()
    eng = make_engine(cfg, ctx, seed=args.seed, max_slots=args.max_slots,
                      max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24))
                    .tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s), f={eng.tracker.f():.2f}")
    for r in reqs[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
