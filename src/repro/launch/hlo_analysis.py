"""Loop-aware HLO analysis: exact collective bytes + dot FLOPs per device.

``compiled.cost_analysis()`` does NOT multiply while-loop bodies by their
trip counts (verified empirically), so we parse the optimized HLO text:

1. split into computations; record every collective (kind, result bytes,
   replica-group size) and every ``dot`` (flops from shapes) per computation;
2. build the call graph (while bodies with parsed trip counts, fusions,
   calls, conditionals);
3. DFS from ``main`` accumulating multipliers → totals that include every
   scanned layer.

Wire-byte model per device (bidirectional ring): all-gather out·(g-1)/g,
reduce-scatter out·(g-1), all-reduce 2·size·(g-1)/g, all-to-all
size·(g-1)/g, collective-permute size.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) (?:\([^\n]*\) -> [^\n{]+)?\{",
                      re.M)
_COLL_RE = re.compile(
    r"= ((?:\([^)]*\))|(?:\S+)) (all-gather|all-reduce|reduce-scatter"
    r"|all-to-all|collective-permute)(?:-start)?\(")
_CALL_RE = re.compile(
    r"(?:calls=%?([\w\.\-]+))|(?:to_apply=%?([\w\.\-]+))"
    r"|(?:body=%?([\w\.\-]+))|(?:condition=%?([\w\.\-]+))"
    r"|(?:branch_computations=\{([^}]*)\})"
    r"|(?:true_computation=%?([\w\.\-]+))|(?:false_computation=%?([\w\.\-]+))")
_WHILE_RE = re.compile(r"while\(.*body=%?([\w\.\-]+), *condition=%?([\w\.\-]+)|"
                       r"while\(.*condition=%?([\w\.\-]+), *body=%?([\w\.\-]+)")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DOT_RE = re.compile(r"= (\S+) dot\((.*?)\), lhs_batch_dims")
_CONST_RE = re.compile(r"%?([\w\.\-]+) = s32\[\] constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\(([^)]*)\), direction=(LT|LE|GT|GE)")


def _type_bytes(t: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CompInfo:
    collectives: list = field(default_factory=list)   # (kind, bytes, gsize)
    dot_flops: float = 0.0
    children: list = field(default_factory=list)      # (name, multiplier)


def _split_computations(txt: str) -> dict[str, str]:
    comps: dict[str, str] = {}
    cur, buf = None, []
    for line in txt.splitlines():
        m = re.match(r"^(ENTRY )?%?([\w\.\-]+) (\([^)]*\)|.*?) -> .*\{", line) \
            or re.match(r"^(ENTRY )?%?([\w\.\-]+) \{", line)
        if m and not line.startswith(" "):
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur = m.group(2)
            buf = [line]
        elif cur is not None:
            buf.append(line)
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _group_size(line: str, n_devices: int) -> int:
    m = _RG_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return n_devices


_INSTR_RE = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = (\S+(?: \S+\])?)\s")


def _symbol_shapes(body: str) -> dict[str, list[int]]:
    """instruction name → result dims (first array shape in its type)."""
    table: dict[str, list[int]] = {}
    for line in body.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        sm = _SHAPE_RE.search(line.split(" = ", 1)[1])
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            table[m.group(1)] = dims
    return table


def _dot_flops(line: str, symbols: dict[str, list[int]]) -> float:
    """2 · prod(result dims) · contracted size (lhs operand looked up)."""
    m = re.search(r"= (\S+) dot\(", line)
    if not m:
        return 0.0
    om = _SHAPE_RE.search(m.group(1))
    if not om:
        return 0.0
    out_elems = math.prod(int(d) for d in om.group(2).split(",") if d) \
        if om.group(2) else 1
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = re.search(r"dot\(([^)]*)\)", line)
    csize = 1
    if cm and ops:
        opstr = ops.group(1).strip()
        sm = re.match(r"(\w+)\[([\d,]*)\]", opstr)
        if sm and sm.group(1) in _DTYPE_BYTES:
            # newer XLA inlines operand types: dot(f32[8,64]{1,0} %copy, …)
            lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
        else:
            lhs_name = opstr.split(",")[0].strip().lstrip("%")
            lhs_dims = symbols.get(lhs_name)
        if lhs_dims:
            for i in (int(x) for x in cm.group(1).split(",") if x):
                if i < len(lhs_dims):
                    csize *= lhs_dims[i]
    return 2.0 * out_elems * csize


def _trip_count(cond_body: str, consts: dict[str, int]) -> int | None:
    m = _CMP_RE.search(cond_body)
    limit = None
    if m:
        for arg in m.group(1).split(","):
            arg = arg.strip().lstrip("%")
            if arg in consts:
                limit = consts[arg]
        if limit is not None:
            return limit if m.group(2) in ("LT", "GT") else limit + 1
    # fallback: any s32 constant inside the condition
    cs = re.findall(r"constant\((\d+)\)", cond_body)
    if cs:
        return int(cs[-1])
    return None


def analyze_hlo(txt: str, n_devices: int) -> dict:
    comps = _split_computations(txt)
    # global s32 constants (trip-count limits live inside cond computations)
    infos: dict[str, CompInfo] = {}
    entry = None
    for name, body in comps.items():
        if "ENTRY" in body.splitlines()[0]:
            entry = name
        info = CompInfo()
        symbols = _symbol_shapes(body)
        for line in body.splitlines():
            cm = _COLL_RE.search(line)
            if cm and "-done" not in line:
                kind = cm.group(2)
                nbytes = _type_bytes(cm.group(1))
                g = _group_size(line, n_devices)
                is_f32 = cm.group(1).startswith("f32") or \
                    "(f32" in cm.group(1)
                info.collectives.append((kind, nbytes, g, is_f32))
            if " dot(" in line:
                info.dot_flops += _dot_flops(line, symbols)
            wm = _WHILE_RE.search(line)
            if wm:
                bodyc = wm.group(1) or wm.group(4)
                condc = wm.group(2) or wm.group(3)
                # newer XLA annotates the loop directly — prefer that over
                # reverse-engineering the condition computation
                km = re.search(r'known_trip_count[^0-9]*(\d+)', line)
                if km:
                    tc = int(km.group(1))
                else:
                    consts = dict((n, int(v)) for n, v in
                                  _CONST_RE.findall(comps.get(condc, "")))
                    tc = _trip_count(comps.get(condc, ""), consts) or 1
                info.children.append((bodyc, tc))
                info.children.append((condc, tc))
            else:
                for g in _CALL_RE.finditer(line):
                    for target in g.groups():
                        if target:
                            for t in target.split(","):
                                t = t.strip().lstrip("%")
                                if t in comps:
                                    info.children.append((t, 1))
        infos[name] = info
    entry = entry or (next(iter(comps)) if comps else None)

    totals = defaultdict(float)
    coll_bytes = 0.0
    coll_bytes_norm = 0.0   # bf16-normalized: CPU XLA legalizes bf16 dots to
    # f32 and hoists the converts across collectives (verified via op_name
    # provenance); on TPU (native bf16 MXU) those tensors stay bf16, so f32
    # collective payloads are counted at half width for the TPU roofline.
    coll_by_kind = defaultdict(float)
    flops = 0.0
    warnings: list[str] = []
    seen_stack: set[str] = set()

    def wire(kind: str, nbytes: float, g: int) -> float:
        if g <= 1:
            return 0.0
        if kind == "all-gather":
            return nbytes * (g - 1) / g
        if kind == "reduce-scatter":
            return nbytes * (g - 1)
        if kind == "all-reduce":
            return 2.0 * nbytes * (g - 1) / g
        if kind == "all-to-all":
            return nbytes * (g - 1) / g
        return float(nbytes)  # collective-permute

    def visit(name: str, mult: float):
        nonlocal coll_bytes, coll_bytes_norm, flops
        if name in seen_stack:  # recursion guard
            return
        info = infos.get(name)
        if info is None:
            return
        seen_stack.add(name)
        for kind, nbytes, g, is_f32 in info.collectives:
            w = wire(kind, nbytes, g) * mult
            coll_bytes += w
            coll_bytes_norm += w * (0.5 if is_f32 else 1.0)
            coll_by_kind[kind] += w
            totals[f"n_{kind}"] += mult
        flops += info.dot_flops * mult
        for child, m in info.children:
            visit(child, mult * m)
        seen_stack.discard(name)

    if entry:
        visit(entry, 1.0)
    return {
        "collective_bytes_per_device": coll_bytes,
        "collective_bytes_per_device_bf16norm": coll_bytes_norm,
        "collective_bytes_by_kind": dict(coll_by_kind),
        "collective_counts": {k: v for k, v in totals.items()},
        "dot_flops_per_device": flops,
        "n_computations": len(comps),
    }
