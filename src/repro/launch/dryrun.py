import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Never set this globally — smoke tests and benches must see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective analyses.

    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # sweep, subprocess per cell

Per cell we record (experiments/dryrun/<arch>__<shape>__<mesh>.json):
  · compile success, wall times
  · memory_analysis(): per-device argument/output/temp bytes (fits < 16 GB?)
  · cost_analysis() flops (per-iteration; loop-corrected totals come from
    the HLO analyzer) + loop-aware dot-FLOPs and collective wire bytes
  · collective op counts by kind (the collective schedule)
EXPERIMENTS.md §Dry-run / §Roofline are generated from these JSONs.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_configs, cell_supported, get_config
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.serve.kv_cache import cache_defs
from repro.sharding import params as prm
from repro.sharding.axes import DEFAULT_RULES, ShardCtx
from repro.train.optimizer import OptConfig
from repro.train.step import abstract_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# ≥40 B-param models shard optimizer state over the pod axis too (ZeRO over
# DCI) — without it a 16 GB v5e cannot hold its slice of a 236 B model. The
# cost shows up as pod-crossing all-gathers in the §Roofline collective term.
BIG_MODELS = {"deepseek-v2-236b", "phi3.5-moe-42b-a6.6b", "jamba-v0.1-52b"}

# grad-accumulation microbatches per train cell (activation-memory control;
# chosen so peak_bytes_per_device < 16 GB with headroom)
MICROBATCHES = {"deepseek-v2-236b": 16, "phi3.5-moe-42b-a6.6b": 4,
                "jamba-v0.1-52b": 8, "internvl2-26b": 4, "nemotron-4-15b": 2,
                "mistral-nemo-12b": 2, "whisper-large-v3": 2}


# §Perf iteration 2 (see EXPERIMENTS.md): parameter-sharding stage per cell.
#   - inference (prefill/decode): params shard over `model` only — FSDP
#     gathers per decoded token were measured at ~12 GB/step on phi-42B.
#   - train ≤52 B params: ZeRO-2 — params replicated over `data`, only
#     moments/grads sharded; kills the per-microbatch weight all-gathers.
#   - train 236 B (deepseek): ZeRO-3 stays (params don't fit replicated).
ZERO3_MODELS = {"deepseek-v2-236b"}


def make_ctx(cfg: ModelConfig, multi_pod: bool,
             kind: str = "train") -> ShardCtx:
    # NOTES from the §Perf log (EXPERIMENTS.md):
    #  · ZeRO-over-pod (embed → ("pod","data")) triggers XLA SPMD
    #    "involuntary full rematerialization" (replicated dots, 6.6× flops)
    #    — int8 moments + microbatching is the memory lever instead.
    #  · ZeRO-2 for train was measured WORSE than ZeRO-3 once the shard_map
    #    MLP landed (activation gathers dominate; params-replicated memory
    #    costs 2-9 GiB/dev for nothing) — train keeps ZeRO-3.
    #  · inference replicates params over `data` (TP over `model` only):
    #    FSDP gathers were ~12 GB per decoded token on phi-42B.
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(DEFAULT_RULES)
    if kind != "train" and cfg.name not in ZERO3_MODELS:
        # inference: TP over `model` only — except 236 B-class models whose
        # bf16 params (29.5 GB per model-shard) cannot replicate over data
        rules["embed"] = ()
    return ShardCtx(mesh=mesh, rules=rules)


def moment_ctx(ctx: ShardCtx) -> ShardCtx:
    """Optimizer moments always shard over data (ZeRO-2's sharded state)."""
    return ShardCtx(mesh=ctx.mesh, rules=dict(DEFAULT_RULES))


def sds(ctx: ShardCtx, shape, dtype, axes):
    return jax.ShapeDtypeStruct(tuple(shape), dtype,
                                sharding=ctx.sharding(axes, shape))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.enc_dec:
            Td = cfg.max_decoder_len
            return {
                "frames": sds(ctx, (B, S, cfg.d_model), jnp.float32,
                              ("batch", "seq", None)),
                "tokens": sds(ctx, (B, Td), jnp.int32, ("batch", None)),
                "targets": sds(ctx, (B, Td), jnp.int32, ("batch", None)),
                "mask": sds(ctx, (B, Td), jnp.float32, ("batch", None)),
            }
        out = {
            "tokens": sds(ctx, (B, S), jnp.int32, ("batch", "seq")),
            "targets": sds(ctx, (B, S), jnp.int32, ("batch", "seq")),
            "mask": sds(ctx, (B, S), jnp.float32, ("batch", "seq")),
        }
        if cfg.frontend != "none":
            out["frontend_embed"] = sds(
                ctx, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32,
                ("batch", None, None))
        return out
    if shape.kind == "prefill":
        if cfg.enc_dec:
            return {"frames": sds(ctx, (B, S, cfg.d_model), jnp.float32,
                                  ("batch", "seq", None))}
        out = {"tokens": sds(ctx, (B, S), jnp.int32, ("batch", "seq"))}
        if cfg.frontend != "none":
            out["frontend_embed"] = sds(
                ctx, (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32,
                ("batch", None, None))
        return out
    # decode: one new token against a seq_len cache
    msize = ctx.axis_size("model")
    cdefs = cache_defs(cfg, B, S, msize)
    return {
        "cache": prm.abstract(cdefs, ctx),
        "tokens": sds(ctx, (B,), jnp.int32, ("batch",)),
        "pos": sds(ctx, (B,), jnp.int32, ("batch",)),
    }


def build_lowerable(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardCtx):
    """→ (jitted fn, args tuple of specs)."""
    specs = input_specs(cfg, shape, ctx)
    if shape.kind == "train":
        import jax.numpy as _jnp
        ocfg = OptConfig(
            moments_dtype="int8" if cfg.name in BIG_MODELS else "float32")
        accum = _jnp.bfloat16 if cfg.name == "deepseek-v2-236b" else _jnp.float32
        mb = MICROBATCHES.get(cfg.name, 1)
        if "pod" in ctx.mesh.shape:      # per-device batch already halves
            mb = max(1, mb // 2)
        step = make_train_step(cfg, ocfg, ctx, microbatches=mb,
                               accum_dtype=accum)
        state = abstract_state(cfg, ctx, ocfg=ocfg)
        return jax.jit(step, donate_argnums=(0,)), (state, specs)
    pdefs_abstract = prm.abstract(
        __import__("repro.models.model", fromlist=["model_defs"]).model_defs(cfg), ctx)
    if shape.kind == "prefill":
        from repro.serve.prefill import prefill_step_fn
        step = prefill_step_fn(cfg, ctx)
        if cfg.enc_dec:
            return jax.jit(step), (pdefs_abstract, specs["frames"])
        if cfg.frontend != "none":
            return jax.jit(step), (pdefs_abstract, specs["tokens"],
                                   specs["frontend_embed"])
        return jax.jit(step), (pdefs_abstract, specs["tokens"])
    from repro.serve.decode import serve_step_fn
    step = serve_step_fn(cfg, ctx)
    return (jax.jit(step, donate_argnums=(1,)),
            (pdefs_abstract, specs["cache"], specs["tokens"], specs["pos"]))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR, force: bool = False) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False}
    ok, why = cell_supported(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    try:
        ctx = make_ctx(cfg, multi_pod, shape.kind)
        n_dev = ctx.mesh.size
        fn, args = build_lowerable(cfg, shape, ctx)
        t0 = time.time()
        with ctx.mesh:
            lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        hlo = analyze_hlo(txt, n_dev)
        arg_b = getattr(ma, "argument_size_in_bytes", 0)
        out_b = getattr(ma, "output_size_in_bytes", 0)
        tmp_b = getattr(ma, "temp_size_in_bytes", 0)
        alias_b = getattr(ma, "alias_size_in_bytes", 0)
        peak = arg_b + out_b + tmp_b - alias_b
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            n_devices=n_dev,
            memory={"argument_bytes": arg_b, "output_bytes": out_b,
                    "temp_bytes": tmp_b, "alias_bytes": alias_b,
                    "peak_bytes_per_device": peak,
                    "fits_hbm": bool(peak < HBM_BYTES),
                    "hbm_frac": round(peak / HBM_BYTES, 4)},
            cost_analysis={"flops_per_iter_hint": ca.get("flops", 0.0)},
            hlo=hlo,
            hlo_chars=len(txt),
        )
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: compile "
              f"{t2 - t1:.1f}s peak/dev {peak/2**30:.2f} GiB "
              f"coll {hlo['collective_bytes_per_device']/2**20:.1f} MiB "
              f"dotflops {hlo['dot_flops_per_device']:.3e}")
    except Exception as e:  # record the failure — these are bugs to fix
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {arch} {shape_name} {mesh_name}: FAIL {e}")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def all_cells():
    for arch in sorted(all_configs()):
        for shape_name in SHAPES:
            for mesh in ("single", "multi"):
                yield arch, shape_name, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    if args.all:
        failures = 0
        for arch, shape_name, mesh in all_cells():
            out_path = os.path.join(
                args.out, f"{arch}__{shape_name}__{mesh}.json")
            if os.path.exists(out_path) and not args.force:
                continue
            # subprocess per cell: isolates XLA heap + survives crashes
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                 "--shape", shape_name, "--mesh", mesh, "--out", args.out],
                env=dict(os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src")),
                capture_output=True, text=True, timeout=3600)
            if r.returncode != 0:
                failures += 1
                print(f"[dryrun-all] {arch} {shape_name} {mesh} subprocess "
                      f"failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
        print(f"[dryrun-all] done, {failures} subprocess failures")
        return
    assert args.arch and args.shape
    rec = run_cell(args.arch, args.shape, args.mesh == "multi", args.out,
                   args.force)
    sys.exit(0 if rec.get("ok") else 1)


if __name__ == "__main__":
    main()
