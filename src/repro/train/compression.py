"""Error-feedback gradient compression (distributed-optimization trick).

Two codecs for the cross-pod / cross-tier gradient exchange, both with
error-feedback residuals so the compression error is re-injected next step
(Karimireddy et al. '19 — EF makes biased compressors convergent):

* ``int8``  — per-tensor absmax scaling to int8 (4× over fp32 on the wire);
* ``topk``  — keep the top-k fraction of entries by magnitude (sparse).

In-graph use: ``compress_decompress`` simulates the wire round-trip inside
``train_step`` (numerics). Host use: the heterogeneous batch partitioner
ships actual int8 buffers between tiers (bytes measured in benchmarks).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)


def _int8_roundtrip(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(F32) * scale


def _topk_roundtrip(x, frac):
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_decompress(grads, residuals, ccfg: CompressionConfig):
    """→ (decompressed grads as seen post-allreduce, new residuals)."""
    if ccfg.kind == "none":
        return grads, residuals

    def one(g, r):
        x = g.astype(F32) + r
        if ccfg.kind == "int8":
            y = _int8_roundtrip(x)
        elif ccfg.kind == "topk":
            y = _topk_roundtrip(x, ccfg.topk_frac)
        else:
            raise ValueError(ccfg.kind)
        return y.astype(g.dtype), x - y

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))


def wire_bytes(grads, ccfg: CompressionConfig) -> int:
    """Bytes on the wire for one exchange (benchmark accounting)."""
    n = sum(x.size for x in jax.tree.leaves(grads))
    if ccfg.kind == "int8":
        return n + 4 * len(jax.tree.leaves(grads))
    if ccfg.kind == "topk":
        k = int(n * ccfg.topk_frac)
        return k * (4 + 4)          # value + index
    return n * 4
