"""Fault-tolerant training loop.

Responsibilities: init-or-restore, periodic (async) checkpoints, per-step
throughput accounting feeding the StragglerMonitor, failure handling
(restore newest valid checkpoint, optionally after an elastic re-mesh), and
a bounded restart budget. This is the loop examples/train_lm.py and the
fault-tolerance tests drive.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional

import jax

from repro.configs.base import ModelConfig
from repro.core.straggler import StragglerMonitor
from repro.sharding.axes import ShardCtx
from repro.train import checkpoint as ckpt
from repro.train.compression import CompressionConfig
from repro.train.optimizer import OptConfig
from repro.train.step import init_state, make_train_step


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    max_restarts: int = 3
    log_every: int = 10


@dataclass
class LoopResult:
    state: Any
    history: list[dict] = field(default_factory=list)
    restarts: int = 0
    resumed_from: Optional[int] = None


def train_loop(cfg: ModelConfig, ocfg: OptConfig, lcfg: LoopConfig,
               ctx: ShardCtx, data_iter: Iterator[dict],
               ccfg: CompressionConfig | None = None,
               failure_injector=None,
               on_step: Optional[Callable[[int, dict], None]] = None,
               seed: int = 0) -> LoopResult:
    step_fn = jax.jit(make_train_step(cfg, ocfg, ctx, ccfg))
    monitor = StragglerMonitor()
    result = LoopResult(state=None)

    def init_or_restore():
        state = init_state(cfg, jax.random.PRNGKey(seed), ctx, ccfg)
        restored = ckpt.restore(lcfg.ckpt_dir, state, ctx)
        if restored is not None:
            state, at = restored
            result.resumed_from = at
            return state, at
        return state, 0

    state, start = init_or_restore()
    step = start
    restarts = 0
    while step < lcfg.total_steps:
        try:
            batch = next(data_iter)
            if failure_injector is not None:
                failure_injector.maybe_fail(step)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            tokens = float(metrics.get("tokens", 0.0))
            monitor.observe("self", max(int(tokens), 1), dt)
            step += 1
            row = {"step": step, "dt": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            result.history.append(row)
            if on_step:
                on_step(step, row)
            if step % lcfg.ckpt_every == 0 or step == lcfg.total_steps:
                if lcfg.async_ckpt:
                    ckpt.save_async(lcfg.ckpt_dir, state, step)
                else:
                    ckpt.save(lcfg.ckpt_dir, state, step)
        except StopIteration:
            break
        except Exception:
            restarts += 1
            result.restarts = restarts
            if restarts > lcfg.max_restarts:
                raise
            ckpt.wait_pending()
            state, step = init_or_restore()
    ckpt.wait_pending()
    result.state = state
    return result
