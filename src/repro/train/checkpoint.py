"""Atomic, checksummed, resumable checkpointing (no orbax).

Layout:  <dir>/step_<N>/manifest.json + one .npy-ish blob per leaf.
Protocol: write to <dir>/tmp_<N>, fsync, atomic rename — a crash mid-save
never corrupts the previous checkpoint. Restore walks steps newest-first
and falls back past any checkpoint whose CRCs don't verify (fault-tolerance
test injects corruption). Optional async save on a worker thread.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's .npy format can't round-trip ml_dtypes (bf16 → void); store such
# arrays as same-width uints and restore the logical dtype from the manifest
_EXOTIC = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
           "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
           "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, state, step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(state)
    manifest = {"step": step, "tensors": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _EXOTIC:
            arr = arr.view(_EXOTIC[logical][0])
        fn = key.replace("/", "__").replace("[", "_").replace("]", "_") + ".npy"
        path = os.path.join(tmp, fn)
        with open(path, "wb") as f:
            np.lib.format.write_array(f, arr)
            f.flush()
            os.fsync(f.fileno())
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["tensors"][key] = {"file": fn, "crc": crc,
                                    "shape": list(arr.shape),
                                    "dtype": logical}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_save_thread: Optional[threading.Thread] = None


def save_async(ckpt_dir: str, state, step: int) -> threading.Thread:
    """Snapshot to host, then write on a worker thread (overlaps compute)."""
    global _save_thread
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    if _save_thread is not None:
        _save_thread.join()
    _save_thread = threading.Thread(target=save,
                                    args=(ckpt_dir, host_state, step),
                                    daemon=True)
    _save_thread.start()
    return _save_thread


def wait_pending() -> None:
    if _save_thread is not None:
        _save_thread.join()


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for n in os.listdir(ckpt_dir):
        if n.startswith("step_"):
            try:
                steps.append(int(n.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def _verify(path: str) -> Optional[dict]:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for key, meta in manifest["tensors"].items():
            with open(os.path.join(path, meta["file"]), "rb") as f:
                if zlib.crc32(f.read()) != meta["crc"]:
                    return None
        return manifest
    except Exception:
        return None


def restore(ckpt_dir: str, like_state: Any, ctx=None) -> tuple[Any, int] | None:
    """Restore the newest *valid* checkpoint into the structure (and
    shardings, if `like_state` leaves carry them) of `like_state`."""
    for step in reversed(available_steps(ckpt_dir)):
        path = os.path.join(ckpt_dir, f"step_{step}")
        manifest = _verify(path)
        if manifest is None:
            continue
        flat_like, treedef = _flatten(like_state)
        leaves = []
        ok = True
        for key, like in flat_like.items():
            meta = manifest["tensors"].get(key)
            if meta is None or tuple(meta["shape"]) != tuple(like.shape):
                ok = False
                break
            with open(os.path.join(path, meta["file"]), "rb") as f:
                arr = np.lib.format.read_array(f)
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(_EXOTIC[meta["dtype"]][1])
            sharding = getattr(like, "sharding", None)
            if sharding is not None and hasattr(sharding, "mesh"):
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        if ok:
            return jax.tree.unflatten(treedef, leaves), step
    return None
