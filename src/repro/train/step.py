"""The jitted training step: fwd+bwd → clip → (compress) → AdamW.

``make_train_step`` builds the function that launch/dryrun.py lowers for
every (arch × train shape × mesh) cell, and that examples/train_lm.py runs
for real. State is a plain dict pytree: params / m / v / step (+ ef).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn, model_defs
from repro.sharding import params as prm
from repro.sharding.axes import ShardCtx
from repro.train.compression import (CompressionConfig, compress_decompress,
                                     init_residuals)
from repro.train.optimizer import (OptConfig, adamw_update,
                                   clip_by_global_norm, init_moments)

F32 = jnp.float32


def init_state(cfg: ModelConfig, key, ctx: ShardCtx,
               ccfg: CompressionConfig | None = None,
               ocfg: OptConfig | None = None):
    params = prm.materialize_sharded(model_defs(cfg), key, ctx)
    mom = init_moments(params, ocfg)
    state = {"params": params, "m": mom["m"], "v": mom["v"],
             "step": jnp.zeros((), jnp.int32)}
    if ccfg and ccfg.kind != "none":
        state["ef"] = init_residuals(params)
    return state


def abstract_state(cfg: ModelConfig, ctx: ShardCtx,
                   ccfg: CompressionConfig | None = None,
                   ocfg: OptConfig | None = None,
                   mctx: ShardCtx | None = None):
    """ShapeDtypeStruct state tree for the dry-run (no allocation).
    `mctx` (optional) shards moments differently from params — ZeRO-2."""
    from repro.train.optimizer import _Q_MIN_SIZE
    import numpy as np
    ocfg = ocfg or OptConfig()
    defs = model_defs(cfg)
    params = prm.abstract(defs, ctx)
    ctx = mctx or ctx   # moments below use the moment ctx

    def f32_like(d):
        return jax.ShapeDtypeStruct(d.shape, F32, sharding=d.sharding)

    def moment_like(d: prm.ParamDef, kind: str):
        size = int(np.prod(d.shape))
        if (ocfg.moments_dtype == "int8" and len(d.shape) >= 2
                and size >= _Q_MIN_SIZE):
            if kind == "v":
                return jax.ShapeDtypeStruct(
                    d.shape, jnp.bfloat16,
                    sharding=ctx.sharding(d.axes, d.shape))
            return {
                "q": jax.ShapeDtypeStruct(
                    d.shape, jnp.int8, sharding=ctx.sharding(d.axes, d.shape)),
                "s": jax.ShapeDtypeStruct(
                    d.shape[:-1] + (1,), F32,
                    sharding=ctx.sharding(d.axes[:-1] + (None,),
                                          d.shape[:-1] + (1,))),
            }
        return jax.ShapeDtypeStruct(d.shape, F32,
                                    sharding=ctx.sharding(d.axes, d.shape))

    state = {"params": params,
             "m": prm.tree_map(lambda d: moment_like(d, "m"), defs),
             "v": prm.tree_map(lambda d: moment_like(d, "v"), defs),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if ccfg and ccfg.kind != "none":
        state["ef"] = jax.tree.map(f32_like, params)
    return state


def make_train_step(cfg: ModelConfig, ocfg: OptConfig, ctx: ShardCtx,
                    ccfg: CompressionConfig | None = None,
                    microbatches: int = 1, accum_dtype=F32,
                    mctx: ShardCtx | None = None):
    """microbatches > 1 → grad accumulation over a scan: activation temps
    shrink ~linearly (what lets the ≳40 B MoE cells fit 16 GB/chip) and each
    microbatch's grad psum overlaps the next microbatch's compute (XLA
    schedules the previous reduce against the next fwd). accum_dtype=bf16
    halves the accumulator for the very largest models (Adam's per-
    coordinate normalisation tolerates the ~1% accumulation noise).

    `mctx` (ZeRO-2): the gradient accumulator + update math live in the
    *moment* sharding (data-sharded) while params stay replicated over
    data — the per-microbatch grad all-reduce becomes a reduce-scatter and
    one all-gather of the updated params happens per step."""
    ccfg = ccfg or CompressionConfig()

    def shard_grads(g):
        if mctx is None:
            return g
        from repro.models.model import model_defs
        shardings = prm.shardings(model_defs(cfg), mctx)
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, shardings)

    def grads_of(params, batch):
        def lf(p):
            return loss_fn(cfg, p, batch, ctx)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
            return shard_grads(grads), metrics
        resh = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)
        g0 = shard_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params))

        def body(acc, mb):
            (loss, metrics), g = grads_of(params, mb)
            g = shard_grads(g)
            acc = jax.tree.map(lambda a, x: a + x.astype(accum_dtype), acc, g)
            acc = shard_grads(acc)
            return acc, metrics

        gsum, ms = jax.lax.scan(body, g0, resh)
        grads = jax.tree.map(lambda g, p: (g / microbatches).astype(p.dtype),
                             gsum, params)
        metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        return shard_grads(grads), metrics

    def train_step(state, batch):
        grads, metrics = accumulate(state["params"], batch)
        grads, gn = clip_by_global_norm(grads, ocfg.clip_norm)
        new_state = dict(state)
        if ccfg.kind != "none":
            grads, new_state["ef"] = compress_decompress(
                grads, state["ef"], ccfg)
        p, m, v, lr = adamw_update(state["params"], grads, state["m"],
                                   state["v"], state["step"], ocfg)
        new_state.update(params=p, m=m, v=v, step=state["step"] + 1)
        metrics = dict(metrics, grad_norm=gn, lr=lr)
        return new_state, metrics

    return train_step
