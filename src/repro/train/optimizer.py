"""AdamW (decoupled weight decay) + global-norm clip + warmup-cosine schedule.

Built from scratch (no optax). Moments are fp32 regardless of param dtype
(bf16 params + fp32 m/v is the mixed-precision recipe sized in DESIGN.md);
the update math runs in fp32 and casts back.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # "float32" | "int8": low-precision moments — m is absmax-int8 per last
    # axis (linear quant is safe for the *numerator*), v is bf16 (exponent
    # bits keep relative precision, so 1/(√v+ε) never explodes — linear
    # int8 for v crushes small entries to 0 and diverges; verified in
    # tests). 8 bytes/param → ~3. What lets the 236 B cell fit v5e HBM.
    moments_dtype: str = "float32"


_Q_MIN_SIZE = 4096      # leaves smaller than this stay fp32 (norms, biases)


def _quantize_moment(x32):
    s = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0 + 1e-30
    q = jnp.round(x32 / s).astype(jnp.int8)
    return {"q": q, "s": s}


def _dequantize_moment(st):
    return st["q"].astype(F32) * st["s"]


def _is_quantized(st) -> bool:
    return isinstance(st, dict) and "q" in st


def encode_moment(x32, like_param, ocfg: "OptConfig", kind: str = "m"):
    if (ocfg.moments_dtype == "int8" and like_param.ndim >= 2
            and like_param.size >= _Q_MIN_SIZE):
        if kind == "m":
            return _quantize_moment(x32)
        return x32.astype(jnp.bfloat16)          # v: bf16, never int8
    return x32


def decode_moment(st):
    if _is_quantized(st):
        return _dequantize_moment(st)
    return st.astype(F32)


def schedule(ocfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(F32)
    warm = step / jnp.maximum(ocfg.warmup_steps, 1)
    prog = jnp.clip((step - ocfg.warmup_steps)
                    / jnp.maximum(ocfg.decay_steps - ocfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = ocfg.min_lr_ratio + (1 - ocfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return ocfg.lr * jnp.where(step < ocfg.warmup_steps, warm, cos)


def init_moments(params, ocfg: OptConfig | None = None):
    ocfg = ocfg or OptConfig()

    zm = lambda p: encode_moment(jnp.zeros(p.shape, F32), p, ocfg, "m")
    zv = lambda p: encode_moment(jnp.zeros(p.shape, F32), p, ocfg, "v")
    return {"m": jax.tree.map(zm, params), "v": jax.tree.map(zv, params)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), gn


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalars (1-D leaves)."""
    return True  # refined per-leaf below by ndim


def adamw_update(params, grads, m, v, step, ocfg: OptConfig):
    """Functional AdamW. step is the *previous* count (0-based)."""
    lr = schedule(ocfg, step)
    t = (step + 1).astype(F32)
    bc1 = 1 - ocfg.b1 ** t
    bc2 = 1 - ocfg.b2 ** t

    def upd(p, g, m_st, v_st):
        g32 = g.astype(F32)
        m_n = ocfg.b1 * decode_moment(m_st) + (1 - ocfg.b1) * g32
        v_n = ocfg.b2 * decode_moment(v_st) + (1 - ocfg.b2) * jnp.square(g32)
        mhat = m_n / bc1
        vhat = v_n / bc2
        upd32 = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            upd32 = upd32 + ocfg.weight_decay * p.astype(F32)
        p_n = p.astype(F32) - lr * upd32
        return (p_n.astype(p.dtype), encode_moment(m_n, p, ocfg, "m"),
                encode_moment(v_n, p, ocfg, "v"))

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(m, is_leaf=_is_quantized)
    flat_v = jax.tree.leaves(v, is_leaf=_is_quantized)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    mtd = jax.tree.structure(m, is_leaf=_is_quantized)
    vtd = jax.tree.structure(v, is_leaf=_is_quantized)
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(mtd, [o[1] for o in out])
    new_v = jax.tree.unflatten(vtd, [o[2] for o in out])
    return new_p, new_m, new_v, lr
