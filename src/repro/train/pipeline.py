"""Pipeline parallelism (GPipe-style) over a ``stage`` mesh axis.

Each stage owns a contiguous group of layers; microbatches stream through a
`collective_permute` ring inside ``shard_map``. The schedule is the classic
(S + M - 1)-tick loop: at tick t, stage s computes microbatch (t - s) if it
is in range, then passes activations to stage s+1. Bubble fraction =
(S-1)/(S+M-1), reported by :func:`bubble_fraction`.

The production dry-run meshes use DP×TP(×EP/SP) — the assigned shapes don't
need PP — but the mechanism is exercised end-to-end (loss matches the
unpipelined reference bit-for-bit modulo reduction order) by
``tests/test_pipeline.py`` on a 4-stage host mesh, and composes with the
other axes (the stage shard_map is just another mesh dim).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def pipeline_apply(mesh: Mesh, layer_fn, params_stacked, x_mb, *,
                   axis: str = "stage"):
    """Run ``layer_fn(params_i, h)`` for each of S stages over M microbatches.

    params_stacked: pytree with leading axis S (stage-major layer groups),
    sharded over `axis`. x_mb: (M, mb, …) microbatched input, replicated.
    Returns (M, mb, …) outputs after all S stages.
    """
    S = mesh.shape[axis]
    M = x_mb.shape[0]

    def local(params_loc, x_loc):
        # params_loc: (1, …) this stage's layer group; x_loc: (M, mb, …)
        sidx = jax.lax.axis_index(axis)
        p_stage = jax.tree.map(lambda x: x[0], params_loc)
        mb_shape = x_loc.shape[1:]
        buf = jnp.zeros(mb_shape, x_loc.dtype)      # activation in flight
        out = jnp.zeros_like(x_loc)
        perm_fwd = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            buf, out = carry
            mb_idx = t - sidx                       # microbatch at this stage
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 ingests a fresh microbatch; others use the ring buffer
            fresh = jax.lax.dynamic_index_in_dim(
                x_loc, jnp.clip(mb_idx, 0, M - 1), 0, keepdims=False)
            h_in = jnp.where(sidx == 0, fresh, buf)
            h_out = layer_fn(p_stage, h_in)
            h_out = jnp.where(active, h_out, jnp.zeros_like(h_out))
            # last stage writes its finished microbatch
            write_idx = jnp.clip(mb_idx, 0, M - 1)
            do_write = active & (sidx == S - 1)
            cur = jax.lax.dynamic_index_in_dim(out, write_idx, 0,
                                               keepdims=False)
            upd = jnp.where(do_write, h_out, cur)
            out = jax.lax.dynamic_update_index_in_dim(out, upd, write_idx, 0)
            # ring-shift activations to the next stage
            buf = jax.lax.ppermute(h_out, axis, perm_fwd)
            return (buf, out)

        buf, out = jax.lax.fori_loop(0, S + M - 1, tick, (buf, out))
        # `out` only valid on the last stage → broadcast it to all stages
        out = jax.lax.psum(
            jnp.where(sidx == S - 1, out, jnp.zeros_like(out)), axis)
        return out

    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_mb)
