"""Elastic re-meshing: shrink/regrow the data axis when tiers die.

When the StragglerMonitor excludes a tier (or a device failure surfaces as
an exception), the loop rebuilds the mesh from the surviving devices —
keeping the ``model`` axis intact (TP degree is a property of the weights'
layout) and shrinking ``data`` — then reshards the training state through
host memory. Losing data-parallel replicas changes only throughput, not
model math, so training resumes bit-exactly from the same state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding.axes import ShardCtx


def build_mesh(devices, model_size: int, axis_names=("data", "model")) -> Mesh:
    n = len(devices)
    assert n % model_size == 0, (n, model_size)
    arr = np.array(devices).reshape(n // model_size, model_size)
    return Mesh(arr, axis_names)


def shrink_mesh(ctx: ShardCtx, failed_indices: set[int]) -> ShardCtx:
    """Drop whole data-rows containing failed devices; rebuild the mesh."""
    mesh = ctx.mesh
    devs = np.array(mesh.devices)            # (data, model) [or (pod,d,m)]
    if devs.ndim == 3:                       # collapse pod into data
        devs = devs.reshape(-1, devs.shape[-1])
    keep_rows = [i for i in range(devs.shape[0])
                 if not any(d.id in failed_indices for d in devs[i])]
    assert keep_rows, "no healthy data rows left"
    new = Mesh(devs[keep_rows], ("data", "model"))
    return ShardCtx(mesh=new, rules=ctx.rules)


def reshard_state(state, defs_tree_specs, new_ctx: ShardCtx):
    """Host round-trip reshard (single-controller CPU path).

    defs_tree_specs: pytree of logical-axes tuples matching `state` leaves
    (or None to replicate)."""

    def move(leaf, axes):
        arr = np.asarray(jax.device_get(leaf))
        if axes is None:
            return jax.device_put(arr)
        return jax.device_put(arr, new_ctx.sharding(axes, arr.shape))

    return jax.tree.map(move, state, defs_tree_specs)


class FailureInjector:
    """Deterministic failure schedule for fault-tolerance tests:
    {step: exception | device_index}."""

    def __init__(self, schedule: dict[int, Exception]):
        self.schedule = dict(schedule)
        self.fired: list[int] = []

    def maybe_fail(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.append(step)
            raise self.schedule[step]


class DeviceFailure(RuntimeError):
    def __init__(self, device_index: int):
        super().__init__(f"simulated failure of device {device_index}")
        self.device_index = device_index
