"""Emit the generated sections of EXPERIMENTS.md from dry-run artifacts.

    PYTHONPATH=src python experiments/make_report.py [--dir experiments/dryrun]
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import (analyze_cell, load_cells,  # noqa: E402
                                 roofline_fraction, table)


def dryrun_table(d: str) -> str:
    rows = ["| arch | shape | mesh | status | compile s | peak GiB/dev | "
            "fits HBM | collectives (AG/AR/RS/A2A/CP) | HLO dot-FLOPs/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    n_ok = n_skip = 0
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if r.get("skipped"):
            n_skip += 1
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped (sub-quadratic rule) | – | – | – | – | – |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"**FAILED** {r.get('error', '')[:60]} | | | | | |")
            continue
        n_ok += 1
        cc = r["hlo"]["collective_counts"]
        counts = "/".join(str(int(cc.get(f"n_{k}", 0))) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
        m = r["memory"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compile_s']} | {m['peak_bytes_per_device'] / 2**30:.2f} "
            f"| {'yes' if m['fits_hbm'] else 'no*'} | {counts} "
            f"| {r['hlo']['dot_flops_per_device']:.2e} |")
    head = (f"{n_ok} cells compiled (lower+compile on the production mesh), "
            f"{n_skip} skipped by the long_500k sub-quadratic rule "
            f"(DESIGN.md §4).\n\n")
    return head + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__),
                                                  "dryrun"))
    args = ap.parse_args()
    print("## §Dry-run (generated)\n")
    print(dryrun_table(args.dir))
    print("\n## §Roofline — single pod 16×16 (generated)\n")
    cells = load_cells(args.dir)
    print(table(cells, "single"))
    print("\n### multi-pod 2×16×16\n")
    print(table(cells, "multi"))
    singles = [c for c in cells if c.mesh == "single"]
    if singles:
        mean = sum(roofline_fraction(c) for c in singles) / len(singles)
        tr = [c for c in singles if c.shape == "train_4k"]
        mean_tr = sum(roofline_fraction(c) for c in tr) / max(len(tr), 1)
        print(f"\nmean roofline fraction (all single-pod cells): {mean:.4f}; "
              f"train cells only: {mean_tr:.4f}")


if __name__ == "__main__":
    main()
